//! The [`Backend`] trait — one execution model behind the [`super::Session`]
//! facade — and its three built-in implementations:
//!
//! * [`AnalyticBackend`] — the closed-form model ([`crate::arch::perf`]),
//!   fast enough for full Fig. 7 sweeps;
//! * [`EventSimBackend`] — the transaction-level event-driven simulator
//!   ([`crate::arch::event_sim`] / [`crate::arch::workload_sim`]) with real
//!   PCA saturation/discharge dynamics;
//! * [`FunctionalBackend`] — the integer XNOR-bitcount reference
//!   ([`crate::functional::bnn`]), carrying arithmetic correctness through
//!   the same report shape (timing delegated to the analytic model).
//!
//! All three consume the same `(AcceleratorConfig, GemmLayer, MappingPolicy)`
//! inputs and produce the same [`LayerReport`] / [`Report`], so any
//! accelerator — OXBNN variants and the ROBIN/LIGHTBULB baselines alike —
//! compares apples-to-apples across execution models.

use std::collections::BTreeMap;

use super::report::{LayerReport, Report, ShardBreakdown};
use super::session::ApiError;
use crate::arch::accelerator::{AcceleratorConfig, BitcountMode};
use crate::arch::workload_sim::PipelineTrace;
use crate::mapping::layer::GemmLayer;
use crate::mapping::scheduler::MappingPolicy;
use crate::plan::{ExecutionPlan, ShardPlan, ShardPolicy};
use crate::sim::stats::SimStats;
use crate::workloads::Workload;

/// Which execution model a session runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Closed-form analytic model (default; full-sweep fast path).
    Analytic,
    /// Event-driven transaction-level simulation (detailed, slower).
    Event,
    /// Integer functional reference (correctness-carrying).
    Functional,
}

impl BackendKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Analytic => "analytic",
            BackendKind::Event => "event",
            BackendKind::Functional => "functional",
        }
    }

    /// All kinds, in documentation order.
    pub fn all() -> [BackendKind; 3] {
        [BackendKind::Analytic, BackendKind::Event, BackendKind::Functional]
    }

    /// Instantiate the built-in backend of this kind.
    pub fn create(&self) -> Box<dyn Backend + Send> {
        match self {
            BackendKind::Analytic => Box::new(AnalyticBackend),
            BackendKind::Event => Box::new(EventSimBackend),
            BackendKind::Functional => Box::new(FunctionalBackend::default()),
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for BackendKind {
    type Err = ApiError;

    fn from_str(s: &str) -> Result<BackendKind, ApiError> {
        match s {
            "analytic" | "perf" => Ok(BackendKind::Analytic),
            "event" | "event-driven" | "sim" => Ok(BackendKind::Event),
            "functional" | "bnn" => Ok(BackendKind::Functional),
            other => Err(ApiError::UnknownBackend(other.to_string())),
        }
    }
}

/// The mapping policy an accelerator's bitcount hardware implies: PCA
/// designs keep every slice of a VDP on one XPE (Fig. 5(b)); psum-reduction
/// designs spread slices across the XPC (Fig. 5(a)).
pub fn default_policy(cfg: &AcceleratorConfig) -> MappingPolicy {
    match cfg.bitcount {
        BitcountMode::Pca { .. } => MappingPolicy::PcaLocal,
        BitcountMode::Reduction { .. } => MappingPolicy::SlicedSpread,
    }
}

/// One execution model. Implementations are configuration-free: the
/// accelerator under evaluation arrives with every call, which is what
/// lets one backend sweep many accelerators (and any accelerator run on
/// many backends).
pub trait Backend {
    /// Which kind this backend is (stamped into reports).
    fn kind(&self) -> BackendKind;

    /// Evaluate one GEMM layer on one accelerator.
    fn run_layer(
        &mut self,
        cfg: &AcceleratorConfig,
        layer: &GemmLayer,
        policy: MappingPolicy,
    ) -> LayerReport;

    /// Evaluate a whole workload (one inference frame). The default runs
    /// layers sequentially and sums their latencies; backends that model
    /// cross-layer effects (fetch/compute overlap) override this.
    fn run_workload(
        &mut self,
        cfg: &AcceleratorConfig,
        workload: &Workload,
        policy: MappingPolicy,
    ) -> Report {
        let layers: Vec<LayerReport> = workload
            .layers
            .iter()
            .map(|l| self.run_layer(cfg, l, policy))
            .collect();
        let frame: f64 = layers.iter().map(|l| l.latency_s).sum();
        Report::from_layers(self.kind(), cfg, &workload.name, layers, frame)
    }

    /// Evaluate a pre-compiled [`ExecutionPlan`] (the [`super::Session`]
    /// entry point — plans come from the session's
    /// [`crate::plan::PlanCache`]). The default ignores the compiled
    /// mapping and delegates to [`Backend::run_workload`]; backends that
    /// consume the mapping itself (the event simulator) override this to
    /// stream it instead of recompiling.
    fn run_planned(&mut self, plan: &ExecutionPlan) -> Report {
        self.run_workload(&plan.accelerator, &plan.workload, plan.policy)
    }

    /// Evaluate `batch` back-to-back frames of a pre-compiled plan. The
    /// default models frames as strictly sequential (one frame simulated,
    /// batch latency multiplied) and ignores `pipelined` and `steal` —
    /// only backends that can genuinely overlap frames honor them. The
    /// event backend overrides this to run the whole batch through one
    /// shared event space when `pipelined` is set (see
    /// [`crate::arch::workload_sim::simulate_frames_pipelined`]),
    /// with bounded work-stealing past admission-blocked units enabled
    /// by `steal`.
    fn run_planned_batched(
        &mut self,
        plan: &ExecutionPlan,
        batch: usize,
        _pipelined: bool,
        _steal: bool,
    ) -> Report {
        self.run_planned(plan).with_batch(batch)
    }

    /// Evaluate a model sharded across `shard.chips()` accelerators (the
    /// [`super::SessionBuilder::chips`] path). The default ignores the
    /// shard geometry and runs the underlying [`ShardPlan::plan`] as a
    /// single (grouped) accelerator — backends with a genuine multi-chip
    /// timing model (event, analytic) override it to charge the
    /// inter-chip transfer channel and report the per-chip breakdown.
    /// K = 1 groups must stay indistinguishable from the unsharded path
    /// (pinned by `tests/scaleout.rs`).
    fn run_planned_sharded(
        &mut self,
        shard: &ShardPlan,
        batch: usize,
        pipelined: bool,
        steal: bool,
    ) -> Report {
        self.run_planned_batched(&shard.plan, batch, pipelined, steal)
    }
}

// ---------------------------------------------------------------------------
// Analytic
// ---------------------------------------------------------------------------

/// Closed-form analytic model (wraps [`crate::arch::perf`]). The mapping
/// policy is implied by the bitcount mode, so the `policy` argument does
/// not change the result here.
///
/// When handed a compiled [`ExecutionPlan`] (the Session path), the
/// backend is **plan-aware**: slices/VDP counts are read off each
/// [`crate::plan::LayerPlan`] instead of being recomputed, and the compute
/// term uses the plan's longest per-XPE queue (`max_queue_len · τ`) rather
/// than the perfect-balance `ceil(passes / XPEs) · τ`. The event simulator
/// serializes each XPE's queue, so on unbalanced layers (small FC tails
/// whose VDP count doesn't divide the XPE grid) the perfect-balance model
/// systematically overestimates FPS; the plan correction closes most of
/// that gap (pinned in `rust/tests/sim_vs_analytic.rs`).
#[derive(Debug, Clone, Copy, Default)]
pub struct AnalyticBackend;

impl Backend for AnalyticBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Analytic
    }

    fn run_layer(
        &mut self,
        cfg: &AcceleratorConfig,
        layer: &GemmLayer,
        _policy: MappingPolicy,
    ) -> LayerReport {
        let p = crate::arch::perf::layer_perf(cfg, layer);
        let mut timing = BTreeMap::new();
        timing.insert("compute_s".to_string(), p.compute_s);
        timing.insert("memory_s".to_string(), p.memory_s);
        timing.insert("reduce_s".to_string(), p.reduce_s);
        timing.insert("fixed_s".to_string(), p.fixed_s);
        LayerReport {
            name: p.name,
            latency_s: p.latency_s,
            dynamic_energy_j: p.dynamic_energy_j,
            passes: p.passes,
            psums: p.psums,
            timing,
            counters: BTreeMap::new(),
            energy_breakdown: BTreeMap::new(),
        }
    }

    /// Plan-aware path: reuse the compiled slice tables and correct the
    /// compute term for per-XPE load imbalance (the critical path is the
    /// longest XPE queue, which the plan knows in O(1)).
    fn run_planned(&mut self, plan: &ExecutionPlan) -> Report {
        plan_aware_report(self, plan)
    }

    /// Pipelined batches get a closed-form overlap estimate driven by the
    /// plan's **exact admission thresholds** ([`FramePlan::need_acts`]):
    /// layer `l` starts once the receptive-field prefix of layer `l−1` has
    /// drained (its activations taken as draining uniformly over the
    /// layer's span), and in steady state the batch completes one frame
    /// per bottleneck. The bottleneck is admission-aware on memory too:
    /// every frame's operands cross the ONE shared eDRAM fetch channel,
    /// so the steady-state rate can never beat the serialized sum of the
    /// per-layer memory terms — without that floor the estimate was
    /// systematically optimistic on memory-bound chains. The event
    /// backend remains the reference; `sim_vs_analytic.rs` pins the gap.
    ///
    /// [`FramePlan::need_acts`]: crate::plan::FramePlan::need_acts
    fn run_planned_batched(
        &mut self,
        plan: &ExecutionPlan,
        batch: usize,
        pipelined: bool,
        _steal: bool,
    ) -> Report {
        let report = plan_aware_report(self, plan);
        if !pipelined {
            return report.with_batch(batch);
        }
        let fp = crate::plan::FramePlan::new(plan, 1);
        let mut start = 0.0_f64;
        let mut end = 0.0_f64;
        let mut bottleneck = 0.0_f64;
        let mut fetch_serial = 0.0_f64;
        for (l, lr) in report.layers.iter().enumerate() {
            if l > 0 {
                let produced = plan.layers[l - 1].vdp_count() as f64;
                let frac = fp.need_acts(l, 0) as f64 / produced;
                start += frac * report.layers[l - 1].latency_s;
            }
            end = (start + lr.latency_s).max(end);
            bottleneck = bottleneck.max(lr.latency_s);
            fetch_serial += lr.timing.get("memory_s").copied().unwrap_or(0.0);
        }
        let frame = end;
        let bottleneck = bottleneck.max(fetch_serial);
        let makespan = frame + (batch - 1) as f64 * bottleneck;
        report.with_pipelined_batch(batch, frame, makespan)
    }

    /// Closed-form K-chip estimate mirroring
    /// [`ShardPlan::analytic_batched_fps`], but through the full report
    /// machinery: each layer keeps the plan's queue-critical compute term
    /// (already shrunk by the scaled grid under VdpSplit), the memory term
    /// is split across the K parallel eDRAM channels under VdpSplit, and
    /// cross-chip edges add their serialized link time. Steady state
    /// streams one frame per bottleneck — the slowest layer (VdpSplit) or
    /// slowest pipeline stage (LayerPipeline), never faster than the
    /// shared link can carry a frame's cross-chip activations.
    fn run_planned_sharded(
        &mut self,
        shard: &ShardPlan,
        batch: usize,
        pipelined: bool,
        steal: bool,
    ) -> Report {
        if shard.chips() == 1 {
            return self.run_planned_batched(&shard.plan, batch, pipelined, steal);
        }
        let base = plan_aware_report(self, &shard.plan);
        let split = if shard.vdp_split() { shard.chips() as f64 } else { 1.0 };
        let mut layers = base.layers;
        for (l, lr) in layers.iter_mut().enumerate() {
            let compute_s = lr.timing.get("compute_s").copied().unwrap_or(0.0);
            let reduce_s = lr.timing.get("reduce_s").copied().unwrap_or(0.0);
            let fixed_s = lr.timing.get("fixed_s").copied().unwrap_or(0.0);
            let memory_s =
                lr.timing.get("memory_s").copied().unwrap_or(0.0) / split;
            let transfer_s = shard.transfer_time_s(l);
            lr.timing.insert("memory_s".to_string(), memory_s);
            lr.timing.insert("transfer_s".to_string(), transfer_s);
            lr.latency_s =
                compute_s.max(memory_s).max(reduce_s) + fixed_s + transfer_s;
        }
        let frame: f64 = layers.iter().map(|l| l.latency_s).sum();
        let report = Report::from_layers(
            self.kind(),
            &shard.base,
            &shard.plan.workload.name,
            layers,
            frame,
        );
        let link_serial =
            shard.transfers_per_frame() as f64 * shard.link.occupancy_s();
        let breakdown = ShardBreakdown {
            chips: shard.chips(),
            policy: shard.policy().as_str().to_string(),
            chip_idle_fraction: Vec::new(),
            link_busy_s: link_serial,
            link_transfers: shard.transfers_per_frame() as u64,
        };
        let per_chip_static = shard.base.static_power_w();
        if !pipelined {
            return report
                .with_batch(batch)
                .with_shard(breakdown, per_chip_static);
        }
        // Per-channel fetch serialization, mirroring the single-chip
        // estimate: under VdpSplit every chip's eDRAM channel stages its
        // 1/K share of EVERY layer, so the steady-state rate is floored
        // by the sum of the (already split) memory terms; under
        // LayerPipeline each stage's fetch serial is bounded by the stage
        // latency sum, so the stage bottleneck already covers it.
        let fetch_serial: f64 = report
            .layers
            .iter()
            .map(|l| l.timing.get("memory_s").copied().unwrap_or(0.0))
            .sum();
        let bottleneck = match shard.policy() {
            ShardPolicy::VdpSplit => report
                .layers
                .iter()
                .map(|l| l.latency_s)
                .fold(0.0_f64, f64::max)
                .max(fetch_serial),
            ShardPolicy::LayerPipeline => {
                let mut stages = vec![0.0_f64; shard.chips()];
                for (l, lr) in report.layers.iter().enumerate() {
                    stages[shard.chip_of_layer[l]] += lr.latency_s;
                }
                stages.into_iter().fold(0.0_f64, f64::max)
            }
        }
        .max(link_serial);
        let makespan = frame + (batch - 1) as f64 * bottleneck;
        report
            .with_pipelined_batch(batch, frame, makespan)
            .with_shard(breakdown, per_chip_static)
    }
}

/// The shared plan-aware evaluation for backends whose timing is the
/// closed-form model (analytic, and functional via its delegated timing):
/// run each layer through the backend's own `run_layer`, then replace the
/// perfect-balance compute term (`ceil(passes / XPEs) · τ`) with the
/// compiled plan's critical path (`max_queue_len · τ`) and recompose the
/// layer latency. On layers whose VDP count divides the XPE grid the two
/// are identical; on unbalanced tails the queue-based term matches what
/// the event simulator actually serializes. One implementation keeps the
/// two backends reporting identical latencies through the facade.
fn plan_aware_report<B: Backend + ?Sized>(backend: &mut B, plan: &ExecutionPlan) -> Report {
    let cfg = &plan.accelerator;
    let tau = cfg.tau_s();
    let layers: Vec<LayerReport> = plan
        .layers
        .iter()
        .map(|lp| {
            let mut lr = backend.run_layer(cfg, &lp.layer, plan.policy);
            debug_assert_eq!(lr.passes, lp.total_passes() as u64);
            let compute_s = lp.max_queue_len() as f64 * tau;
            let memory_s = lr.timing.get("memory_s").copied().unwrap_or(0.0);
            let reduce_s = lr.timing.get("reduce_s").copied().unwrap_or(0.0);
            let fixed_s = lr.timing.get("fixed_s").copied().unwrap_or(0.0);
            lr.timing.insert("compute_s".to_string(), compute_s);
            lr.latency_s = compute_s.max(memory_s).max(reduce_s) + fixed_s;
            lr
        })
        .collect();
    let frame: f64 = layers.iter().map(|l| l.latency_s).sum();
    Report::from_layers(backend.kind(), cfg, &plan.workload.name, layers, frame)
}

// ---------------------------------------------------------------------------
// Event-driven
// ---------------------------------------------------------------------------

/// Transaction-level event-driven simulation (wraps
/// [`crate::arch::event_sim`]); whole-workload runs reproduce
/// [`crate::arch::workload_sim::simulate_frame`]'s fetch/compute overlap
/// (pinned by the `event_backend_matches_simulate_frame` test).
#[derive(Debug, Clone, Copy, Default)]
pub struct EventSimBackend;

/// Shape a finished layer's event stats into the unified report slice.
fn layer_report_from_stats(name: &str, stats: &SimStats) -> LayerReport {
    let mut counters = stats.counters().clone();
    counters.insert("events".to_string(), stats.events_processed);
    LayerReport {
        name: name.to_string(),
        latency_s: stats.end_time_s,
        dynamic_energy_j: stats.total_energy_j(),
        passes: stats.counter("passes"),
        psums: stats.counter("psums"),
        timing: BTreeMap::new(),
        counters,
        energy_breakdown: stats.energy_breakdown().clone(),
    }
}

impl Backend for EventSimBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Event
    }

    fn run_layer(
        &mut self,
        cfg: &AcceleratorConfig,
        layer: &GemmLayer,
        policy: MappingPolicy,
    ) -> LayerReport {
        let stats = crate::arch::event_sim::simulate_layer(cfg, layer, policy);
        layer_report_from_stats(&layer.name, &stats)
    }

    /// Whole frames compile (or receive) an [`ExecutionPlan`] and stream
    /// it — see [`EventSimBackend::run_planned`].
    fn run_workload(
        &mut self,
        cfg: &AcceleratorConfig,
        workload: &Workload,
        policy: MappingPolicy,
    ) -> Report {
        self.run_planned(&ExecutionPlan::compile(cfg, workload, policy))
    }

    /// The plan-driven path: every layer streams its compiled pass map
    /// (no schedule materialization, no recompilation on cache hits), and
    /// layers chain with eDRAM prefetch overlap through the same
    /// [`crate::arch::workload_sim::OverlapChain`] recurrence that
    /// [`crate::arch::workload_sim::simulate_frame`] uses (layers run in
    /// separate event spaces there too, so per-layer stats are identical).
    fn run_planned(&mut self, plan: &ExecutionPlan) -> Report {
        let cfg = &plan.accelerator;
        let workload = &plan.workload;
        let mut chain = crate::arch::workload_sim::OverlapChain::new(cfg, workload);
        let layers: Vec<LayerReport> = plan
            .layers
            .iter()
            .map(|lp| {
                let stats = crate::arch::event_sim::simulate_layer_planned(cfg, lp);
                let lr = layer_report_from_stats(&lp.layer.name, &stats);
                chain.step(lr.latency_s);
                lr
            })
            .collect();
        Report::from_layers(
            self.kind(),
            cfg,
            &workload.name,
            layers,
            chain.frame_latency_s(),
        )
    }

    /// Pipelined batches run the whole batch through ONE event space
    /// ([`crate::arch::workload_sim::simulate_frames_pipelined`]): layer
    /// `l+1`'s passes start as soon as their input activations drain, and
    /// frame `f+1` streams into XPEs idled by frame `f`'s tail. The
    /// report's per-layer slice comes from frame 0's units (every frame
    /// runs the identical compiled plan), `frame_latency_s` is frame 0's
    /// completion and `fps` is the honest `batch / makespan` throughput.
    /// Sequential batches keep the `with_batch` multiply. `steal`
    /// enables bounded work-stealing past admission-blocked units (the
    /// default through the Session facade; `--steal off` disables it).
    fn run_planned_batched(
        &mut self,
        plan: &ExecutionPlan,
        batch: usize,
        pipelined: bool,
        steal: bool,
    ) -> Report {
        if !pipelined {
            return self.run_planned(plan).with_batch(batch);
        }
        let trace = crate::arch::workload_sim::simulate_frames_pipelined_opts(
            plan,
            batch,
            crate::plan::AdmissionMode::Exact,
            steal,
        );
        report_from_pipeline_trace(self.kind(), &plan.accelerator, &plan.workload.name, &trace)
            .with_pipelined_batch(batch, trace.frame_latency_s, trace.batch_latency_s)
    }

    /// K-chip groups run through the sharded whole-batch event space
    /// ([`crate::arch::workload_sim::simulate_frames_sharded`]): one
    /// shared scheduler over all K chips, per-chip eDRAM channels, and
    /// the serialized inter-chip transfer channel gating cross-chip
    /// admission on *arrivals*. The per-chip config (`shard.base`) is the
    /// accelerator the report charges — [`Report::with_shard`] then
    /// re-accounts static power for K chips and attaches the per-chip
    /// idle / link breakdown. K = 1 delegates to the unsharded path for
    /// bit-exact identity.
    fn run_planned_sharded(
        &mut self,
        shard: &ShardPlan,
        batch: usize,
        pipelined: bool,
        steal: bool,
    ) -> Report {
        if shard.chips() == 1 {
            return self.run_planned_batched(&shard.plan, batch, pipelined, steal);
        }
        let cfg = &shard.base;
        let frames = if pipelined { batch } else { 1 };
        let trace = crate::arch::workload_sim::simulate_frames_sharded_opts(
            shard,
            frames,
            crate::plan::AdmissionMode::Exact,
            steal,
        );
        let breakdown = ShardBreakdown {
            chips: trace.chips,
            policy: shard.policy().as_str().to_string(),
            chip_idle_fraction: trace.chip_idle_fraction(),
            link_busy_s: trace.link_busy_s,
            link_transfers: trace.link_transfers,
        };
        let report = report_from_pipeline_trace(
            self.kind(),
            cfg,
            &shard.plan.workload.name,
            &trace,
        );
        if pipelined {
            report
                .with_pipelined_batch(batch, trace.frame_latency_s, trace.batch_latency_s)
                .with_shard(breakdown, cfg.static_power_w())
        } else {
            report.with_batch(batch).with_shard(breakdown, cfg.static_power_w())
        }
    }
}

/// Shape a whole-batch [`PipelineTrace`] into the unified report: frame
/// 0's unit slices become the per-layer reports (every frame streams the
/// identical compiled plan), and the whole-batch engine diagnostics ride
/// on the first layer's counter map. Shared by the single-chip pipelined
/// path and the sharded path, which differ only in which config and
/// trace they hand in.
fn report_from_pipeline_trace(
    kind: BackendKind,
    cfg: &AcceleratorConfig,
    workload_name: &str,
    trace: &PipelineTrace,
) -> Report {
    let mut layers: Vec<LayerReport> = trace
        .layers
        .iter()
        .map(|lt| {
            let mut counters = BTreeMap::new();
            counters.insert("passes".to_string(), lt.passes);
            counters.insert("pca_readouts".to_string(), lt.pca_readouts);
            counters.insert("mid_vdp_readouts".to_string(), lt.mid_vdp_readouts);
            counters.insert("psums".to_string(), lt.psums);
            counters.insert("activations".to_string(), lt.activations);
            let ledger = crate::arch::event_sim::energy_ledger(
                cfg,
                lt.passes,
                lt.pca_readouts,
                lt.mid_vdp_readouts,
                lt.psums,
            );
            let energy_breakdown: BTreeMap<String, f64> = ledger
                .iter()
                .map(|(k, v)| (k.to_string(), *v))
                .collect();
            LayerReport {
                name: lt.name.clone(),
                // The unit's active span in the shared event space
                // (first pass issue → last activation drain).
                latency_s: lt.done_s - lt.start_s,
                dynamic_energy_j: ledger.iter().map(|(_, v)| *v).sum(),
                passes: lt.passes,
                psums: lt.psums,
                timing: BTreeMap::new(),
                counters,
                energy_breakdown,
            }
        })
        .collect();
    // Whole-batch engine diagnostics must survive into the report —
    // the conformance suite gates on `clamped_events == 0` through the
    // per-layer counter sum, so they ride on the first layer's map.
    if let Some(first) = layers.first_mut() {
        for key in [
            "clamped_events",
            "pca_saturations",
            "pca_discharge_stalls",
            "reduction_inits",
            "peak_pending_events",
            "wake_dispatches",
            "steal_dispatches",
            "stolen_passes",
            "fetch_wake_dispatches",
            "fetch_sweep_skips",
        ] {
            first.counters.insert(key.to_string(), trace.stats.counter(key));
        }
    }
    Report::from_layers(kind, cfg, workload_name, layers, trace.frame_latency_s)
}

// ---------------------------------------------------------------------------
// Functional
// ---------------------------------------------------------------------------

/// Integer XNOR-bitcount reference: recomputes a deterministic sample of
/// each layer's VDPs bit-exactly two ways — whole-vector popcount vs the
/// sliced accumulation an XPE actually performs — and flags VDPs whose
/// bitcount would saturate the PCA (γ). Timing and energy are delegated to
/// the analytic model; the value carried here is the
/// [`super::Correctness`] block in the report.
#[derive(Debug, Clone)]
pub struct FunctionalBackend {
    /// Seed for the synthetic {0,1} operands (deterministic per layer).
    pub seed: u64,
    /// Cap on VDPs recomputed per layer (keeps big layers affordable).
    pub max_checked_vdps: usize,
    /// Which implementation computes the whole-vector bitcount side of
    /// the differential check: bit-packed XNOR + popcount by default
    /// (so every conformance run exercises the packed engine against the
    /// sliced f32 accumulation), `OXBNN_FUNCTIONAL=f32` for the scalar
    /// reference.
    pub mode: crate::functional::FunctionalMode,
}

impl Default for FunctionalBackend {
    fn default() -> Self {
        FunctionalBackend {
            seed: 0xB17C0,
            max_checked_vdps: 256,
            mode: crate::functional::FunctionalMode::from_env(),
        }
    }
}

impl Backend for FunctionalBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Functional
    }

    fn run_layer(
        &mut self,
        cfg: &AcceleratorConfig,
        layer: &GemmLayer,
        _policy: MappingPolicy,
    ) -> LayerReport {
        use crate::mapping::slicing::{slice_xnor_popcount, slices};

        let analytic = crate::arch::perf::layer_perf(cfg, layer);
        let mut rng = crate::util::rng::Rng::new(
            self.seed
                ^ (layer.h as u64).wrapping_mul(0x9E3779B9)
                ^ (layer.s as u64).wrapping_mul(0x85EBCA6B)
                ^ (layer.k as u64),
        );
        let gamma = match cfg.bitcount {
            BitcountMode::Pca { gamma } => Some(gamma),
            BitcountMode::Reduction { .. } => None,
        };
        let slice_plan = slices(layer.s, cfg.n);
        let check = layer.vdp_count().min(self.max_checked_vdps.max(1));
        let mut mismatches = 0u64;
        let mut clamped = 0u64;
        for _ in 0..check {
            let input = rng.bits(layer.s);
            let weight = rng.bits(layer.s);
            let whole = match self.mode {
                crate::functional::FunctionalMode::Packed => {
                    let pi = crate::functional::pack01(&input);
                    let pw = crate::functional::pack01(&weight);
                    crate::functional::xnor_popcount_u64(pi.words(), pw.words(), layer.s)
                }
                crate::functional::FunctionalMode::F32 => {
                    slice_xnor_popcount(&input, &weight)
                }
            };
            let sliced: u64 = slice_plan
                .iter()
                .map(|sl| {
                    slice_xnor_popcount(
                        &input[sl.start..sl.start + sl.len],
                        &weight[sl.start..sl.start + sl.len],
                    )
                })
                .sum();
            if sliced != whole {
                mismatches += 1;
            }
            if let Some(g) = gamma {
                if whole > g {
                    clamped += 1;
                }
            }
        }
        // `passes`/`psums` live in the dedicated LayerReport fields; the
        // counters map carries only what this backend uniquely measures.
        let mut counters = BTreeMap::new();
        counters.insert("checked_vdps".to_string(), check as u64);
        counters.insert("mismatches".to_string(), mismatches);
        counters.insert("pca_clamped".to_string(), clamped);
        // Timing delegates to the analytic model; carry its decomposition
        // so the plan-aware path can apply the same imbalance correction.
        let mut timing = BTreeMap::new();
        timing.insert("compute_s".to_string(), analytic.compute_s);
        timing.insert("memory_s".to_string(), analytic.memory_s);
        timing.insert("reduce_s".to_string(), analytic.reduce_s);
        timing.insert("fixed_s".to_string(), analytic.fixed_s);
        LayerReport {
            name: layer.name.clone(),
            latency_s: analytic.latency_s,
            dynamic_energy_j: analytic.dynamic_energy_j,
            passes: analytic.passes,
            psums: analytic.psums,
            timing,
            counters,
            energy_breakdown: BTreeMap::new(),
        }
    }

    /// Plan-aware path: same per-layer correctness recomputation, with the
    /// delegated analytic timing corrected for per-XPE imbalance through
    /// the one shared [`plan_aware_report`] — the two backends must keep
    /// reporting identical latencies through the facade.
    fn run_planned(&mut self, plan: &ExecutionPlan) -> Report {
        plan_aware_report(self, plan)
    }
}
