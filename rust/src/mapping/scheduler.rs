//! VDP-to-XPE scheduling policies (paper Fig. 5).
//!
//! * [`MappingPolicy::PcaLocal`] — OXBNN's mapping (Fig. 5(b)): *all*
//!   slices of a VDP go to the *same* XPE in consecutive PASSes, so the
//!   PCA accumulates the partial bitcounts in the analog domain and no
//!   psum ever leaves the XPE.
//! * [`MappingPolicy::SlicedSpread`] — prior works' mapping (Fig. 5(a),
//!   ROBIN/LIGHTBULB): the slices of a VDP are spread across the XPEs of
//!   an XPC within one PASS; every PASS therefore emits psums that must be
//!   stored and combined by a psum reduction network.

use super::layer::GemmLayer;
use crate::sim::event::{VdpId, XpeId};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MappingPolicy {
    PcaLocal,
    SlicedSpread,
}

/// One scheduled PASS on one XPE.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScheduledPass {
    pub vdp: VdpId,
    pub slice_idx: usize,
    /// Bits in this slice (N or the tail remainder).
    pub slice_len: usize,
}

/// A complete **materialized** schedule: per-XPE FIFO queues of passes.
///
/// Production simulation does NOT materialize schedules any more — the
/// event path streams the equivalent mapping in O(1)/pass through
/// [`crate::plan::LayerPlan`] (one cursor per XPE instead of one heap
/// struct per pass). `Schedule::plan` remains as the independently
/// written reference implementation: tests and
/// [`crate::plan::LayerPlan::materialize`] use it to prove the streamed
/// enumeration yields exactly these queues.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub policy: MappingPolicy,
    pub n: usize,
    /// queues[xpc][xpe] = ordered passes.
    pub queues: Vec<Vec<Vec<ScheduledPass>>>,
}

impl Schedule {
    /// Build a schedule for `layer` on an accelerator with `xpc_count`
    /// XPCs of `m` XPEs each, XPE size `n`.
    pub fn plan(
        layer: &GemmLayer,
        policy: MappingPolicy,
        n: usize,
        m: usize,
        xpc_count: usize,
    ) -> Schedule {
        assert!(n > 0 && m > 0 && xpc_count > 0);
        let total_xpes = m * xpc_count;
        let slice_lens = super::slicing::slice_sizes(layer.s, n);
        let slices = slice_lens.len();
        let mut queues = vec![vec![Vec::new(); m]; xpc_count];
        match policy {
            MappingPolicy::PcaLocal => {
                // VDP v → XPE (v mod total); its slices run back-to-back.
                for v in 0..layer.vdp_count() {
                    let flat = v % total_xpes;
                    let (xpc, xpe) = (flat / m, flat % m);
                    for (j, &len) in slice_lens.iter().enumerate() {
                        queues[xpc][xpe].push(ScheduledPass {
                            vdp: VdpId(v),
                            slice_idx: j,
                            slice_len: len,
                        });
                    }
                }
            }
            MappingPolicy::SlicedSpread => {
                // Global slice id g = v·slices + j → XPE (g mod total).
                // Slices of one VDP land on adjacent XPEs in the same
                // PASS round (Fig. 5(a)).
                for v in 0..layer.vdp_count() {
                    for j in 0..slices {
                        let g = v * slices + j;
                        let flat = g % total_xpes;
                        let (xpc, xpe) = (flat / m, flat % m);
                        queues[xpc][xpe].push(ScheduledPass {
                            vdp: VdpId(v),
                            slice_idx: j,
                            slice_len: slice_lens[j],
                        });
                    }
                }
            }
        }
        Schedule { policy, n, queues }
    }

    /// Total passes across all XPEs.
    pub fn total_passes(&self) -> usize {
        self.queues
            .iter()
            .flat_map(|xpc| xpc.iter().map(|q| q.len()))
            .sum()
    }

    /// Longest single-XPE queue — the critical path in PASS counts.
    pub fn max_queue_len(&self) -> usize {
        self.queues
            .iter()
            .flat_map(|xpc| xpc.iter().map(|q| q.len()))
            .max()
            .unwrap_or(0)
    }

    /// Iterate (XpeId, &queue).
    pub fn iter_queues(&self) -> impl Iterator<Item = (XpeId, &Vec<ScheduledPass>)> {
        self.queues.iter().enumerate().flat_map(|(c, xpes)| {
            xpes.iter()
                .enumerate()
                .map(move |(e, q)| (XpeId { xpc: c, xpe: e }, q))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{forall, prop_assert, prop_assert_eq, Config};
    use std::collections::BTreeMap;

    fn fig5_layer(s: usize) -> GemmLayer {
        // Fig. 5: H=2 vectors, some S, one output channel each modeled as
        // H=2, K=1.
        GemmLayer::new("fig5", 2, s, 1)
    }

    #[test]
    fn fig5b_pca_local_keeps_vdp_on_one_xpe() {
        // M=2, H=2, N=9, S=15: OXBNN maps both slices of vector 1 to
        // XPE 1 and both slices of vector 2 to XPE 2.
        let sched = Schedule::plan(&fig5_layer(15), MappingPolicy::PcaLocal, 9, 2, 1);
        let q0 = &sched.queues[0][0];
        let q1 = &sched.queues[0][1];
        assert_eq!(q0.len(), 2);
        assert_eq!(q1.len(), 2);
        assert!(q0.iter().all(|p| p.vdp == VdpId(0)));
        assert!(q1.iter().all(|p| p.vdp == VdpId(1)));
        // Slices in order 0 then 1 (PASS 1, PASS 2).
        assert_eq!(q0[0].slice_idx, 0);
        assert_eq!(q0[1].slice_idx, 1);
    }

    #[test]
    fn fig5a_sliced_spread_splits_vdp_across_xpes() {
        // Prior-work mapping: PASS 1 carries slice 1 and 2 of vector 1 on
        // XPE 1 and XPE 2 (both psums of VDP 0 in the same round).
        let sched = Schedule::plan(&fig5_layer(15), MappingPolicy::SlicedSpread, 9, 2, 1);
        let q0 = &sched.queues[0][0];
        let q1 = &sched.queues[0][1];
        assert_eq!(q0[0], ScheduledPass { vdp: VdpId(0), slice_idx: 0, slice_len: 9 });
        assert_eq!(q1[0], ScheduledPass { vdp: VdpId(0), slice_idx: 1, slice_len: 6 });
        assert_eq!(q0[1].vdp, VdpId(1));
        assert_eq!(q1[1].vdp, VdpId(1));
    }

    #[test]
    fn fig5c_single_slice_identical_mappings() {
        // S=9=N: one slice per VDP — both policies produce one pass per
        // XPE and the same assignment.
        let a = Schedule::plan(&fig5_layer(9), MappingPolicy::PcaLocal, 9, 2, 1);
        let b = Schedule::plan(&fig5_layer(9), MappingPolicy::SlicedSpread, 9, 2, 1);
        assert_eq!(a.queues, b.queues);
        assert_eq!(a.total_passes(), 2);
    }

    #[test]
    fn prop_every_slice_scheduled_exactly_once() {
        forall(Config::default().cases(60), |g| {
            let layer = GemmLayer::new(
                "p",
                g.usize_in(1, 20),
                g.usize_in(1, 300),
                g.usize_in(1, 12),
            );
            let n = g.usize_in(1, 64);
            let m = g.usize_in(1, 8);
            let xpcs = g.usize_in(1, 4);
            let policy = if g.bool() {
                MappingPolicy::PcaLocal
            } else {
                MappingPolicy::SlicedSpread
            };
            let sched = Schedule::plan(&layer, policy, n, m, xpcs);
            let expect = layer.total_passes(n);
            prop_assert_eq(sched.total_passes(), expect)?;
            // Each (vdp, slice) appears exactly once.
            let mut seen: BTreeMap<(usize, usize), usize> = BTreeMap::new();
            for (_, q) in sched.iter_queues() {
                for p in q {
                    *seen.entry((p.vdp.0, p.slice_idx)).or_insert(0) += 1;
                }
            }
            prop_assert(seen.values().all(|&c| c == 1), "duplicate or missing slice")?;
            prop_assert_eq(seen.len(), expect)
        });
    }

    #[test]
    fn prop_pca_local_vdp_never_splits() {
        forall(Config::default().cases(60), |g| {
            let layer = GemmLayer::new(
                "p",
                g.usize_in(1, 16),
                g.usize_in(1, 256),
                g.usize_in(1, 8),
            );
            let n = g.usize_in(1, 48);
            let m = g.usize_in(1, 8);
            let xpcs = g.usize_in(1, 3);
            let sched = Schedule::plan(&layer, MappingPolicy::PcaLocal, n, m, xpcs);
            let mut owner: BTreeMap<usize, XpeId> = BTreeMap::new();
            for (id, q) in sched.iter_queues() {
                for p in q {
                    if let Some(prev) = owner.insert(p.vdp.0, id) {
                        prop_assert(prev == id, "VDP split across XPEs under PcaLocal")?;
                    }
                }
            }
            // Slices of each VDP must be queued in ascending order.
            for (_, q) in sched.iter_queues() {
                let mut last: BTreeMap<usize, usize> = BTreeMap::new();
                for p in q {
                    if let Some(prev) = last.insert(p.vdp.0, p.slice_idx) {
                        prop_assert(p.slice_idx == prev + 1, "slices out of order")?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn load_balance_within_one_pass() {
        let layer = GemmLayer::new("b", 64, 512, 16);
        for policy in [MappingPolicy::PcaLocal, MappingPolicy::SlicedSpread] {
            let sched = Schedule::plan(&layer, policy, 19, 19, 3);
            let total = sched.total_passes();
            let xpes = 19 * 3;
            let ideal = total.div_ceil(xpes);
            assert!(
                sched.max_queue_len() <= ideal + layer.slices(19),
                "{:?}: max {} vs ideal {}",
                policy,
                sched.max_queue_len(),
                ideal
            );
        }
    }
}
