//! Vector slicing: decompose size-S binarized vectors into N-bit slices
//! for the XPE's OXG array (paper Section II-B, Fig. 1(c)).

/// Sizes of the slices of an S-bit vector on an N-wide XPE: all full N
/// except a possibly-smaller tail.
pub fn slice_sizes(s: usize, n: usize) -> Vec<usize> {
    assert!(s > 0 && n > 0);
    let full = s / n;
    let rem = s % n;
    let mut out = vec![n; full];
    if rem > 0 {
        out.push(rem);
    }
    out
}

/// A slice descriptor: which bits [start, start+len) of the flat vector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Slice {
    pub index: usize,
    pub start: usize,
    pub len: usize,
}

/// Enumerate slice descriptors for an S-bit vector on an N-wide XPE.
pub fn slices(s: usize, n: usize) -> Vec<Slice> {
    slice_sizes(s, n)
        .into_iter()
        .scan(0usize, |start, len| {
            let sl = Slice { index: 0, start: *start, len };
            *start += len;
            Some(sl)
        })
        .enumerate()
        .map(|(i, mut sl)| {
            sl.index = i;
            sl
        })
        .collect()
}

/// XNOR-bitcount of one slice pair over {0,1} bit vectors — the exact
/// integer arithmetic an XPE performs in one PASS. Used by the event sim
/// and the functional engine.
pub fn slice_xnor_popcount(input: &[f32], weight: &[f32]) -> u64 {
    assert_eq!(input.len(), weight.len());
    input
        .iter()
        .zip(weight)
        .filter(|(a, b)| (**a > 0.5) == (**b > 0.5))
        .count() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{forall, prop_assert, prop_assert_eq, Config};

    #[test]
    fn fig5_case1_s15_n9() {
        // Paper Fig. 1(c)/5: S=15, N=9 → slices of 9 and 6.
        assert_eq!(slice_sizes(15, 9), vec![9, 6]);
        let sl = slices(15, 9);
        assert_eq!(sl.len(), 2);
        assert_eq!((sl[0].start, sl[0].len), (0, 9));
        assert_eq!((sl[1].start, sl[1].len), (9, 6));
    }

    #[test]
    fn fig1_case_s9_n5() {
        // Paper Fig. 1(c): S=9, N=5 → slices of 5 and 4.
        assert_eq!(slice_sizes(9, 5), vec![5, 4]);
    }

    #[test]
    fn exact_fit_no_tail() {
        assert_eq!(slice_sizes(27, 9), vec![9, 9, 9]);
    }

    #[test]
    fn slice_xnor_counts_agreements() {
        let a = [1.0, 0.0, 1.0, 0.0];
        let b = [1.0, 1.0, 0.0, 0.0];
        assert_eq!(slice_xnor_popcount(&a, &b), 2);
        assert_eq!(slice_xnor_popcount(&a, &a), 4);
        let inv: Vec<f32> = a.iter().map(|x| 1.0 - x).collect();
        assert_eq!(slice_xnor_popcount(&a, &inv), 0);
    }

    #[test]
    fn prop_slices_cover_exactly() {
        forall(Config::default().cases(200), |g| {
            let s = g.usize_in(1, 8192);
            let n = g.usize_in(1, 128);
            let sizes = slice_sizes(s, n);
            prop_assert_eq(sizes.iter().sum::<usize>(), s)?;
            prop_assert_eq(sizes.len(), s.div_ceil(n))?;
            prop_assert(sizes.iter().all(|&x| x >= 1 && x <= n), "slice size bounds")?;
            // Only the tail may be short.
            prop_assert(
                sizes[..sizes.len() - 1].iter().all(|&x| x == n),
                "non-tail slices full",
            )
        });
    }

    #[test]
    fn prop_slice_descriptors_contiguous() {
        forall(Config::default().cases(200), |g| {
            let s = g.usize_in(1, 4096);
            let n = g.usize_in(1, 96);
            let ds = slices(s, n);
            let mut pos = 0;
            for (i, d) in ds.iter().enumerate() {
                prop_assert_eq(d.index, i)?;
                prop_assert_eq(d.start, pos)?;
                pos += d.len;
            }
            prop_assert_eq(pos, s)
        });
    }

    #[test]
    fn prop_sliced_popcount_equals_whole() {
        // Summing per-slice bitcounts equals the whole-vector bitcount —
        // the invariant that makes the PCA's psum-free accumulation valid
        // (paper Section IV-B, Fig. 5(b)).
        forall(Config::default().cases(100), |g| {
            let s = g.usize_in(1, 300);
            let n = g.usize_in(1, 64);
            let a = g.bits(s);
            let b = g.bits(s);
            let whole = slice_xnor_popcount(&a, &b);
            let sum: u64 = slices(s, n)
                .iter()
                .map(|sl| {
                    slice_xnor_popcount(
                        &a[sl.start..sl.start + sl.len],
                        &b[sl.start..sl.start + sl.len],
                    )
                })
                .sum();
            prop_assert_eq(sum, whole)
        });
    }
}
