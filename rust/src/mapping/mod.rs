//! Convolution→GEMM flattening, vector slicing and XPE scheduling
//! (paper Section II-B and Section IV-B / Fig. 5).

pub mod layer;
pub mod scheduler;
pub mod slicing;

pub use layer::GemmLayer;
pub use scheduler::{MappingPolicy, Schedule, ScheduledPass};
pub use slicing::{slice_sizes, slice_xnor_popcount, slices, Slice};
