//! GEMM-layer geometry: every BNN layer (conv or FC) is processed as a
//! binarized GEMM after flattening (paper Section II-B / Fig. 1).
//!
//! * A conv layer with C_in input channels, k×k kernels, K output channels
//!   on an H_out×W_out output map becomes H = H_out·W_out input vectors of
//!   size S = k·k·C_in against K weight vectors.
//! * A depthwise conv becomes H = H_out·W_out·C vectors of size S = k·k
//!   against one weight vector each (K = 1, grouped).
//! * An FC layer is H = 1, S = inputs, K = outputs.

/// One flattened GEMM layer.
#[derive(Debug, Clone, PartialEq)]
pub struct GemmLayer {
    pub name: String,
    /// Number of input vectors (output spatial positions).
    pub h: usize,
    /// Vector size (bits per VDP).
    pub s: usize,
    /// Number of weight vectors (output channels).
    pub k: usize,
    /// True if a 2x2 pooling follows this layer (pooling-unit latency).
    pub pool: bool,
}

impl GemmLayer {
    pub fn new(name: impl Into<String>, h: usize, s: usize, k: usize) -> GemmLayer {
        let layer = GemmLayer { name: name.into(), h, s, k, pool: false };
        layer.validate();
        layer
    }

    pub fn with_pool(mut self) -> GemmLayer {
        self.pool = true;
        self
    }

    pub fn validate(&self) {
        assert!(self.h > 0 && self.s > 0 && self.k > 0, "degenerate layer {:?}", self);
    }

    /// Conv layer constructor from geometry.
    pub fn conv(
        name: impl Into<String>,
        out_hw: usize,
        in_channels: usize,
        kernel: usize,
        out_channels: usize,
    ) -> GemmLayer {
        GemmLayer::new(name, out_hw * out_hw, kernel * kernel * in_channels, out_channels)
    }

    /// Depthwise conv: one k×k filter per channel. Modeled as H·W·C tiny
    /// VDPs of size k² (each output element is its own VDP with K = 1).
    pub fn depthwise(
        name: impl Into<String>,
        out_hw: usize,
        channels: usize,
        kernel: usize,
    ) -> GemmLayer {
        GemmLayer::new(name, out_hw * out_hw * channels, kernel * kernel, 1)
    }

    /// Fully connected layer.
    pub fn fc(name: impl Into<String>, inputs: usize, outputs: usize) -> GemmLayer {
        GemmLayer::new(name, 1, inputs, outputs)
    }

    /// Total vector-dot-products in the layer.
    pub fn vdp_count(&self) -> usize {
        self.h * self.k
    }

    /// Slices per VDP for XPE size `n` (paper: ceil(S/N)).
    pub fn slices(&self, n: usize) -> usize {
        assert!(n > 0);
        self.s.div_ceil(n)
    }

    /// Total XPE PASSes to process the layer.
    pub fn total_passes(&self, n: usize) -> usize {
        self.vdp_count() * self.slices(n)
    }

    /// Total 1-bit XNOR operations (equals MAC count of the original
    /// conv/FC layer).
    pub fn bitops(&self) -> u64 {
        self.h as u64 * self.s as u64 * self.k as u64
    }

    /// Operand bits that must be staged from memory once per layer
    /// (inputs H·S + weights S·K); on-chip broadcast covers reuse.
    pub fn operand_bits(&self) -> u64 {
        (self.h * self.s + self.s * self.k) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_flattening_matches_paper_fig1() {
        // Paper Fig. 1: 3x3 weight channel over 5x5 input, stride 1, no
        // padding → 3x3 output? (the figure shows 4 windows for stride 2
        // illustration; here we check the S = 9 flattening rule).
        let l = GemmLayer::conv("c", 3, 1, 3, 1);
        assert_eq!(l.s, 9);
        assert_eq!(l.h, 9);
        assert_eq!(l.vdp_count(), 9);
    }

    #[test]
    fn slices_examples_from_fig5() {
        // Fig. 5: S=15, N=9 → 2 slices; S=9, N=9 → 1 slice.
        let l15 = GemmLayer::new("a", 2, 15, 1);
        let l9 = GemmLayer::new("b", 2, 9, 1);
        assert_eq!(l15.slices(9), 2);
        assert_eq!(l9.slices(9), 1);
    }

    #[test]
    fn totals() {
        let l = GemmLayer::new("t", 4, 100, 8);
        assert_eq!(l.vdp_count(), 32);
        assert_eq!(l.slices(19), 6);
        assert_eq!(l.total_passes(19), 192);
        assert_eq!(l.bitops(), 3200);
        assert_eq!(l.operand_bits(), 400 + 800);
    }

    #[test]
    fn depthwise_geometry() {
        let l = GemmLayer::depthwise("dw", 14, 96, 3);
        assert_eq!(l.h, 14 * 14 * 96);
        assert_eq!(l.s, 9);
        assert_eq!(l.k, 1);
        // Bitops = positions × 9 MACs.
        assert_eq!(l.bitops(), (14 * 14 * 96 * 9) as u64);
    }

    #[test]
    fn fc_geometry() {
        let l = GemmLayer::fc("fc", 512, 1000);
        assert_eq!((l.h, l.s, l.k), (1, 512, 1000));
        assert_eq!(l.vdp_count(), 1000);
    }

    #[test]
    #[should_panic]
    fn degenerate_rejected() {
        GemmLayer::new("bad", 0, 1, 1);
    }
}
