//! GEMM-layer geometry: every BNN layer (conv or FC) is processed as a
//! binarized GEMM after flattening (paper Section II-B / Fig. 1).
//!
//! * A conv layer with C_in input channels, k×k kernels, K output channels
//!   on an H_out×W_out output map becomes H = H_out·W_out input vectors of
//!   size S = k·k·C_in against K weight vectors.
//! * A depthwise conv becomes H = H_out·W_out·C vectors of size S = k·k
//!   against one weight vector each (K = 1, grouped).
//! * An FC layer is H = 1, S = inputs, K = outputs.

/// Convolution geometry of a flattened GEMM layer: the im2col window
/// structure (`kernel`, `stride`, `padding` over an `in_hw × in_hw` input
/// map) the flattening erased. The pipelined event space needs it to admit
/// a consumer's output window exactly when its receptive field has drained
/// ([`crate::plan::FramePlan::need_acts`]); layers without one (FC, or
/// flattenings whose spatial order is not raster, e.g. branchy blocks) get
/// the conservative whole-map wait.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvGeom {
    /// Square kernel side k (k×k window).
    pub kernel: usize,
    pub stride: usize,
    /// Zero padding on each input edge; must be < kernel so every output
    /// window intersects the input map.
    pub padding: usize,
    /// Input feature-map side (the producer layer's output map, after any
    /// 2×2 pooling the producer applies).
    pub in_hw: usize,
}

impl ConvGeom {
    pub fn new(kernel: usize, stride: usize, padding: usize, in_hw: usize) -> ConvGeom {
        let g = ConvGeom { kernel, stride, padding, in_hw };
        g.validate();
        g
    }

    pub fn validate(&self) {
        assert!(
            self.kernel > 0 && self.stride > 0 && self.in_hw > 0,
            "degenerate conv geometry {:?}",
            self
        );
        assert!(
            self.padding < self.kernel,
            "padding must be < kernel so every window touches the map: {:?}",
            self
        );
        assert!(
            self.in_hw + 2 * self.padding >= self.kernel,
            "kernel larger than the padded input map: {:?}",
            self
        );
    }

    /// Output feature-map side: `(in + 2p − k) / s + 1` (floor).
    pub fn out_hw(&self) -> usize {
        (self.in_hw + 2 * self.padding - self.kernel) / self.stride + 1
    }

    /// Row/column of the *last* (bottom-right, raster-maximal) in-bounds
    /// input element that output position `(r, c)` reads. `padding <
    /// kernel` guarantees the window intersects the map, so this is
    /// always defined.
    pub fn last_input_rc(&self, r: usize, c: usize) -> (usize, usize) {
        // r·s + k − 1 ≥ padding because padding < kernel, so no underflow.
        let r_last = (r * self.stride + self.kernel - 1 - self.padding).min(self.in_hw - 1);
        let c_last = (c * self.stride + self.kernel - 1 - self.padding).min(self.in_hw - 1);
        (r_last, c_last)
    }
}

/// One flattened GEMM layer.
#[derive(Debug, Clone, PartialEq)]
pub struct GemmLayer {
    pub name: String,
    /// Number of input vectors (output spatial positions).
    pub h: usize,
    /// Vector size (bits per VDP).
    pub s: usize,
    /// Number of weight vectors (output channels).
    pub k: usize,
    /// True if a 2x2 pooling follows this layer (pooling-unit latency).
    pub pool: bool,
    /// The im2col window structure this GEMM was flattened from, when the
    /// layer is a convolution whose VDPs enumerate output raster positions
    /// spatial-major (position = vdp / channels_per_position). `None` for
    /// FC layers and flattenings with no raster spatial order.
    pub geom: Option<ConvGeom>,
}

impl GemmLayer {
    pub fn new(name: impl Into<String>, h: usize, s: usize, k: usize) -> GemmLayer {
        let layer = GemmLayer { name: name.into(), h, s, k, pool: false, geom: None };
        layer.validate();
        layer
    }

    pub fn with_pool(mut self) -> GemmLayer {
        self.pool = true;
        self
    }

    /// Attach the convolution window structure. The layer's VDPs must
    /// enumerate the geometry's output raster positions spatial-major —
    /// `vdp_count` a multiple of `out_hw²` (regular convs have exactly
    /// `h = out_hw²`; depthwise flattenings carry one VDP per (position,
    /// channel) pair, position-major).
    pub fn with_geom(mut self, geom: ConvGeom) -> GemmLayer {
        geom.validate();
        let out = geom.out_hw();
        assert!(
            self.vdp_count() % (out * out) == 0,
            "layer '{}' ({} VDPs) cannot raster an {}×{} output map",
            self.name,
            self.vdp_count(),
            out,
            out
        );
        self.geom = Some(geom);
        self
    }

    pub fn validate(&self) {
        assert!(self.h > 0 && self.s > 0 && self.k > 0, "degenerate layer {:?}", self);
    }

    /// Conv layer constructor from geometry. Records the im2col window
    /// structure for the common same-convolution case (stride 1, odd
    /// kernel, pad k/2 — output map == input map); other geometries attach
    /// theirs via [`GemmLayer::with_geom`].
    pub fn conv(
        name: impl Into<String>,
        out_hw: usize,
        in_channels: usize,
        kernel: usize,
        out_channels: usize,
    ) -> GemmLayer {
        let layer = GemmLayer::new(
            name,
            out_hw * out_hw,
            kernel * kernel * in_channels,
            out_channels,
        );
        if kernel % 2 == 1 {
            layer.with_geom(ConvGeom::new(kernel, 1, kernel / 2, out_hw))
        } else {
            layer
        }
    }

    /// Depthwise conv: one k×k filter per channel. Modeled as H·W·C tiny
    /// VDPs of size k² (each output element is its own VDP with K = 1).
    pub fn depthwise(
        name: impl Into<String>,
        out_hw: usize,
        channels: usize,
        kernel: usize,
    ) -> GemmLayer {
        GemmLayer::new(name, out_hw * out_hw * channels, kernel * kernel, 1)
    }

    /// Fully connected layer.
    pub fn fc(name: impl Into<String>, inputs: usize, outputs: usize) -> GemmLayer {
        GemmLayer::new(name, 1, inputs, outputs)
    }

    /// Total vector-dot-products in the layer.
    pub fn vdp_count(&self) -> usize {
        self.h * self.k
    }

    /// Slices per VDP for XPE size `n` (paper: ceil(S/N)).
    pub fn slices(&self, n: usize) -> usize {
        assert!(n > 0);
        self.s.div_ceil(n)
    }

    /// Total XPE PASSes to process the layer.
    pub fn total_passes(&self, n: usize) -> usize {
        self.vdp_count() * self.slices(n)
    }

    /// Total 1-bit XNOR operations (equals MAC count of the original
    /// conv/FC layer).
    pub fn bitops(&self) -> u64 {
        self.h as u64 * self.s as u64 * self.k as u64
    }

    /// Operand bits that must be staged from memory once per layer
    /// (inputs H·S + weights S·K); on-chip broadcast covers reuse.
    pub fn operand_bits(&self) -> u64 {
        (self.h * self.s + self.s * self.k) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_flattening_matches_paper_fig1() {
        // Paper Fig. 1: 3x3 weight channel over 5x5 input, stride 1, no
        // padding → 3x3 output? (the figure shows 4 windows for stride 2
        // illustration; here we check the S = 9 flattening rule).
        let l = GemmLayer::conv("c", 3, 1, 3, 1);
        assert_eq!(l.s, 9);
        assert_eq!(l.h, 9);
        assert_eq!(l.vdp_count(), 9);
    }

    #[test]
    fn slices_examples_from_fig5() {
        // Fig. 5: S=15, N=9 → 2 slices; S=9, N=9 → 1 slice.
        let l15 = GemmLayer::new("a", 2, 15, 1);
        let l9 = GemmLayer::new("b", 2, 9, 1);
        assert_eq!(l15.slices(9), 2);
        assert_eq!(l9.slices(9), 1);
    }

    #[test]
    fn totals() {
        let l = GemmLayer::new("t", 4, 100, 8);
        assert_eq!(l.vdp_count(), 32);
        assert_eq!(l.slices(19), 6);
        assert_eq!(l.total_passes(19), 192);
        assert_eq!(l.bitops(), 3200);
        assert_eq!(l.operand_bits(), 400 + 800);
    }

    #[test]
    fn depthwise_geometry() {
        let l = GemmLayer::depthwise("dw", 14, 96, 3);
        assert_eq!(l.h, 14 * 14 * 96);
        assert_eq!(l.s, 9);
        assert_eq!(l.k, 1);
        // Bitops = positions × 9 MACs.
        assert_eq!(l.bitops(), (14 * 14 * 96 * 9) as u64);
    }

    #[test]
    fn fc_geometry() {
        let l = GemmLayer::fc("fc", 512, 1000);
        assert_eq!((l.h, l.s, l.k), (1, 512, 1000));
        assert_eq!(l.vdp_count(), 1000);
    }

    #[test]
    #[should_panic]
    fn degenerate_rejected() {
        GemmLayer::new("bad", 0, 1, 1);
    }

    #[test]
    fn conv_geom_output_map_and_window_reach() {
        // Same conv: 3×3 stride 1 pad 1 on a 32 map → 32 map.
        let same = ConvGeom::new(3, 1, 1, 32);
        assert_eq!(same.out_hw(), 32);
        // Interior window of output (r, c) reaches input (r+1, c+1).
        assert_eq!(same.last_input_rc(5, 7), (6, 8));
        // Bottom-right corner clamps into the map.
        assert_eq!(same.last_input_rc(31, 31), (31, 31));
        // Strided downsample: 3×3 stride 2 pad 1 on 56 → 28.
        let down = ConvGeom::new(3, 2, 1, 56);
        assert_eq!(down.out_hw(), 28);
        assert_eq!(down.last_input_rc(0, 0), (1, 1));
        assert_eq!(down.last_input_rc(27, 0), (55, 1));
        // 1×1 stride 2 projection: 56 → 28, window IS the input element.
        let proj = ConvGeom::new(1, 2, 0, 56);
        assert_eq!(proj.out_hw(), 28);
        assert_eq!(proj.last_input_rc(3, 4), (6, 8));
        // 7×7 stride 2 pad 3 stem: 224 → 112.
        assert_eq!(ConvGeom::new(7, 2, 3, 224).out_hw(), 112);
    }

    #[test]
    fn conv_constructor_records_same_conv_geom() {
        let l = GemmLayer::conv("c", 16, 8, 3, 4);
        let g = l.geom.expect("odd-kernel conv carries its window geometry");
        assert_eq!((g.kernel, g.stride, g.padding, g.in_hw), (3, 1, 1, 16));
        assert_eq!(g.out_hw(), 16);
        // FC and raw GEMM layers carry none.
        assert!(GemmLayer::fc("fc", 64, 10).geom.is_none());
        assert!(GemmLayer::new("g", 4, 9, 2).geom.is_none());
    }

    #[test]
    fn with_geom_accepts_depthwise_position_major_flattening() {
        // Depthwise: one VDP per (position, channel); 14² positions × 96
        // channels rasterize a 14×14 map.
        let l = GemmLayer::depthwise("dw", 14, 96, 3)
            .with_geom(ConvGeom::new(3, 2, 1, 28));
        assert_eq!(l.geom.unwrap().out_hw(), 14);
    }

    #[test]
    #[should_panic(expected = "cannot raster")]
    fn with_geom_rejects_mismatched_output_map() {
        // 4×4 = 16 VDPs cannot raster the 8×8 map this geometry implies.
        let _ = GemmLayer::new("bad", 16, 9, 1).with_geom(ConvGeom::new(3, 1, 1, 8));
    }

    #[test]
    #[should_panic(expected = "padding must be < kernel")]
    fn conv_geom_rejects_full_padding() {
        ConvGeom::new(3, 1, 3, 8);
    }
}
