//! Deterministic-interleaving model checker — a dependency-free
//! mini-loom.
//!
//! A protocol under test is a set of [`Thread`] state machines over a
//! cloneable shared state `S`. Each [`Thread::step`] performs **at most
//! one** shared-state operation (through the [`Shared`] shim, which
//! enforces the discipline) — the granularity at which real threads can
//! interleave around an atomic op or a mutex-protected critical
//! section. The [`Explorer`] then enumerates thread schedules by DFS:
//! at every state it forks one branch per runnable thread, checking the
//! caller's invariant after each step and again at quiescence, and
//! reporting the first violating schedule as a replayable trace.
//!
//! The search is exhaustive up to the configured bounds:
//!
//! * `max_preemptions` — schedules that switch away from a
//!   still-runnable thread more than this many times are pruned
//!   (bounded-preemption search: most real bugs need only a few
//!   preemptions, and the bound tames the factorial blowup).
//! * `max_schedules` — a hard cap on completed schedules, so CI time
//!   stays bounded on larger configurations.
//!
//! Everything is deterministic: threads are stepped in index order, no
//! clocks or randomness exist, and two runs of the same configuration
//! produce identical reports — a failing schedule is a reproducer.
//!
//! Future concurrent code (e.g. the ROADMAP's bounded work-stealing
//! scheduler) adopts this by expressing its protocol as [`Thread`]s over
//! a model of its shared state; see [`super::protocols`] for the shape.

/// Shared-state shim: the only door to `S` during exploration. Counts
/// operations and enforces the one-op-per-step discipline that makes
/// the interleaving semantics meaningful.
#[derive(Debug, Clone)]
pub struct Shared<S> {
    state: S,
    ops: u64,
    in_step: bool,
    accessed: bool,
}

impl<S> Shared<S> {
    pub fn new(state: S) -> Shared<S> {
        Shared { state, ops: 0, in_step: false, accessed: false }
    }

    /// Perform one atomic shared-state operation. Panics if a thread
    /// tries a second operation within a single step — split it into
    /// two steps instead; that split IS the interleaving point.
    pub fn with<R>(&mut self, f: impl FnOnce(&mut S) -> R) -> R {
        assert!(
            !(self.in_step && self.accessed),
            "a Thread::step may perform at most one shared-state op; \
             split the protocol into more steps"
        );
        self.accessed = true;
        self.ops += 1;
        f(&mut self.state)
    }

    /// Read-only view for invariant checks (not counted as an op).
    pub fn peek(&self) -> &S {
        &self.state
    }

    /// Shared-state operations performed so far.
    pub fn ops(&self) -> u64 {
        self.ops
    }

    fn begin_step(&mut self) {
        self.in_step = true;
        self.accessed = false;
    }
}

/// What one scheduling quantum of a thread did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Step {
    /// Made progress; the thread has more steps left.
    Ran,
    /// Cannot progress in this state (e.g. waiting on a guard). A
    /// blocked step must not mutate the shared state.
    Blocked,
    /// Made progress and finished; the thread will not be stepped again.
    Done,
}

/// One protocol participant: a cloneable state machine over `S`.
///
/// Implementors are plain structs with a program counter; `boxed_clone`
/// is the object-safe clone the DFS needs to fork a schedule:
///
/// ```ignore
/// fn boxed_clone(&self) -> Box<dyn Thread<S>> { Box::new(self.clone()) }
/// ```
pub trait Thread<S> {
    fn step(&mut self, shared: &mut Shared<S>) -> Step;
    fn boxed_clone(&self) -> Box<dyn Thread<S>>;
}

impl<S> Clone for Box<dyn Thread<S>> {
    fn clone(&self) -> Self {
        self.boxed_clone()
    }
}

/// A schedule that violated the invariant (or deadlocked): the thread
/// indices in execution order, replayable by construction.
#[derive(Debug, Clone)]
pub struct Violation {
    pub schedule: Vec<usize>,
    pub message: String,
}

/// What an exploration found.
#[derive(Debug, Clone)]
pub struct Report {
    /// Completed (run-to-quiescence) schedules explored.
    pub schedules: u64,
    /// Individual thread steps executed across all schedules.
    pub steps: u64,
    /// Branches pruned by the preemption budget.
    pub pruned: u64,
    /// True if the `max_schedules` cap stopped the search early.
    pub capped: bool,
    /// First invariant violation or deadlock found, if any.
    pub violation: Option<Violation>,
}

impl Report {
    /// Panic with the violating schedule if one was found — the
    /// one-liner protocol tests end with.
    pub fn assert_clean(&self) {
        if let Some(v) = &self.violation {
            panic!("schedule {:?} violates the protocol: {}", v.schedule, v.message);
        }
    }
}

/// DFS over thread schedules with a bounded-preemption budget.
#[derive(Debug, Clone, Copy)]
pub struct Explorer {
    /// Maximum context switches away from a still-runnable thread per
    /// schedule. `usize::MAX` = full exhaustive search.
    pub max_preemptions: usize,
    /// Hard cap on completed schedules (CI time bound).
    pub max_schedules: u64,
}

impl Default for Explorer {
    fn default() -> Explorer {
        Explorer { max_preemptions: usize::MAX, max_schedules: 200_000 }
    }
}

struct Search<'a, S> {
    explorer: Explorer,
    invariant: &'a dyn Fn(&S, bool) -> Result<(), String>,
    report: Report,
    trace: Vec<usize>,
}

impl Explorer {
    /// Explore every schedule of `threads` over `init`, checking
    /// `invariant(state, quiescent)` after each step (`quiescent =
    /// false`) and once more when all threads are done (`quiescent =
    /// true`). Stops at the first violation.
    pub fn explore<S: Clone>(
        &self,
        init: S,
        threads: Vec<Box<dyn Thread<S>>>,
        invariant: impl Fn(&S, bool) -> Result<(), String>,
    ) -> Report {
        let mut search = Search {
            explorer: *self,
            invariant: &invariant,
            report: Report {
                schedules: 0,
                steps: 0,
                pruned: 0,
                capped: false,
                violation: None,
            },
            trace: Vec::new(),
        };
        let done = vec![false; threads.len()];
        let shared = Shared::new(init);
        dfs(&mut search, &shared, &threads, &done, None, 0);
        search.report
    }
}

/// A forked evaluation of one candidate thread's next step.
type Fork<S> = (Shared<S>, Vec<Box<dyn Thread<S>>>, Step);

/// One DFS node: try each non-done thread on a fork of the state.
fn dfs<S: Clone>(
    search: &mut Search<'_, S>,
    shared: &Shared<S>,
    threads: &[Box<dyn Thread<S>>],
    done: &[bool],
    last: Option<usize>,
    preemptions: usize,
) {
    if search.report.violation.is_some() {
        return;
    }
    if search.report.schedules >= search.explorer.max_schedules {
        search.report.capped = true;
        return;
    }
    if done.iter().all(|&d| d) {
        search.report.schedules += 1;
        if let Err(msg) = (search.invariant)(shared.peek(), true) {
            search.report.violation = Some(Violation {
                schedule: search.trace.clone(),
                message: format!("at quiescence: {}", msg),
            });
        }
        return;
    }
    // Evaluate every runnable thread's step on a fork first, so the
    // preemption test below knows which threads are genuinely runnable
    // (a blocked thread does not cost a preemption to switch away from).
    let mut forks: Vec<Option<Fork<S>>> = Vec::with_capacity(threads.len());
    for t in 0..threads.len() {
        if done[t] {
            forks.push(None);
            continue;
        }
        let mut fork_shared = shared.clone();
        let mut fork_threads = threads.to_vec();
        fork_shared.begin_step();
        let step = fork_threads[t].step(&mut fork_shared);
        forks.push(Some((fork_shared, fork_threads, step)));
    }
    let runnable = |t: usize| matches!(&forks[t], Some((_, _, Step::Ran | Step::Done)));
    let any_runnable = (0..threads.len()).any(runnable);
    if !any_runnable {
        let stuck: Vec<usize> = (0..threads.len()).filter(|&t| !done[t]).collect();
        search.report.violation = Some(Violation {
            schedule: search.trace.clone(),
            message: format!("deadlock: threads {:?} are all blocked", stuck),
        });
        return;
    }
    for t in 0..threads.len() {
        if search.report.violation.is_some()
            || search.report.schedules >= search.explorer.max_schedules
        {
            return;
        }
        let Some((fork_shared, fork_threads, step)) = &forks[t] else {
            continue;
        };
        if *step == Step::Blocked {
            continue;
        }
        // A preemption is a switch away from `last` while it could have
        // kept running.
        let cost = match last {
            Some(l) if l != t && runnable(l) => 1,
            _ => 0,
        };
        if preemptions + cost > search.explorer.max_preemptions {
            search.report.pruned += 1;
            continue;
        }
        search.report.steps += 1;
        search.trace.push(t);
        if let Err(msg) = (search.invariant)(fork_shared.peek(), false) {
            search.report.violation = Some(Violation {
                schedule: search.trace.clone(),
                message: msg,
            });
            search.trace.pop();
            return;
        }
        let mut next_done = done.to_vec();
        if *step == Step::Done {
            next_done[t] = true;
        }
        dfs(
            search,
            fork_shared,
            fork_threads,
            &next_done,
            Some(t),
            preemptions + cost,
        );
        search.trace.pop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A thread that increments a counter `n` times, one op per step.
    #[derive(Clone)]
    struct Inc {
        left: usize,
    }

    impl Thread<i64> for Inc {
        fn step(&mut self, shared: &mut Shared<i64>) -> Step {
            shared.with(|s| *s += 1);
            self.left -= 1;
            if self.left == 0 {
                Step::Done
            } else {
                Step::Ran
            }
        }
        fn boxed_clone(&self) -> Box<dyn Thread<i64>> {
            Box::new(self.clone())
        }
    }

    #[test]
    fn counts_interleavings_exactly() {
        // Two threads of two steps each: 4!/(2!·2!) = 6 schedules.
        let threads: Vec<Box<dyn Thread<i64>>> =
            vec![Box::new(Inc { left: 2 }), Box::new(Inc { left: 2 })];
        let report = Explorer::default().explore(0, threads, |&s, quiescent| {
            if quiescent && s != 4 {
                return Err(format!("expected 4 increments, got {}", s));
            }
            Ok(())
        });
        report.assert_clean();
        assert_eq!(report.schedules, 6);
        assert!(!report.capped);
    }

    #[test]
    fn zero_preemption_budget_keeps_only_run_to_completion_orders() {
        // With no preemptions allowed, each thread runs to completion
        // once scheduled: exactly 2 schedules remain.
        let threads: Vec<Box<dyn Thread<i64>>> =
            vec![Box::new(Inc { left: 2 }), Box::new(Inc { left: 2 })];
        let explorer = Explorer { max_preemptions: 0, ..Explorer::default() };
        let report = explorer.explore(0, threads, |_, _| Ok(()));
        assert_eq!(report.schedules, 2);
        assert!(report.pruned > 0);
    }

    /// Two threads each waiting for the other to move first: deadlock.
    #[derive(Clone)]
    struct WaitsFor {
        other_moved_key: usize,
    }

    impl Thread<[bool; 2]> for WaitsFor {
        fn step(&mut self, shared: &mut Shared<[bool; 2]>) -> Step {
            let other = self.other_moved_key;
            let can_go = shared.with(|s| s[other]);
            if can_go {
                Step::Done
            } else {
                Step::Blocked
            }
        }
        fn boxed_clone(&self) -> Box<dyn Thread<[bool; 2]>> {
            Box::new(self.clone())
        }
    }

    #[test]
    fn detects_deadlock() {
        let threads: Vec<Box<dyn Thread<[bool; 2]>>> = vec![
            Box::new(WaitsFor { other_moved_key: 1 }),
            Box::new(WaitsFor { other_moved_key: 0 }),
        ];
        let report = Explorer::default().explore([false, false], threads, |_, _| Ok(()));
        let v = report.violation.expect("circular wait must deadlock");
        assert!(v.message.contains("deadlock"), "{}", v.message);
    }

    #[test]
    fn deterministic_reports() {
        let run = || {
            let threads: Vec<Box<dyn Thread<i64>>> =
                vec![Box::new(Inc { left: 3 }), Box::new(Inc { left: 2 })];
            Explorer::default().explore(0, threads, |_, _| Ok(()))
        };
        let (a, b) = (run(), run());
        assert_eq!(a.schedules, b.schedules);
        assert_eq!(a.steps, b.steps);
    }

    #[test]
    fn violation_reports_replayable_trace() {
        let threads: Vec<Box<dyn Thread<i64>>> =
            vec![Box::new(Inc { left: 1 }), Box::new(Inc { left: 1 })];
        let report = Explorer::default().explore(0, threads, |&s, _| {
            if s >= 2 {
                Err("second increment observed".to_string())
            } else {
                Ok(())
            }
        });
        let v = report.violation.expect("must trip after two steps");
        assert_eq!(v.schedule.len(), 2, "trace covers exactly the violating prefix");
    }
}
