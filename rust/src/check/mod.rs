//! # Static checking
//!
//! Ahead-of-execution verification, distinct from the paper-math models
//! in [`crate::analysis`]: nothing here computes performance or energy —
//! it proves *legality* of what the rest of the crate is about to run.
//!
//! Two engines:
//!
//! * [`planlint`] — a static verifier over compiled [`ExecutionPlan`]s /
//!   [`FramePlan`]s. It re-derives, independently of the plan code, the
//!   invariants the event simulator relies on at runtime (admission
//!   thresholds producible by the producer's raster order, pass-map
//!   conservation, PCA capacity, XPE balance) and reports violations as
//!   [`planlint::Finding`]s with machine-readable codes. The `lint` CLI
//!   subcommand and the serving registry's load gate both run it.
//! * [`interleave`] — a dependency-free deterministic-interleaving model
//!   checker (a mini-loom): protocol state machines express their shared
//!   accesses through a [`interleave::Shared`] shim and the explorer
//!   enumerates thread schedules exhaustively (DFS, optionally bounded by
//!   a preemption budget), checking an invariant after every step and at
//!   quiescence. [`protocols`] models the riskiest concurrent protocols
//!   in the stack against it — three from the serving path plus the
//!   event-sim scheduler's bounded work-stealing handshake.
//!
//! [`ExecutionPlan`]: crate::plan::ExecutionPlan
//! [`FramePlan`]: crate::plan::FramePlan

pub mod interleave;
pub mod planlint;
pub mod protocols;
