//! The riskiest concurrent protocols of the stack — three from the
//! serving path plus the event-sim scheduler's work-stealing frontier —
//! expressed as [`interleave`] models and checked exhaustively.
//!
//! Each model mirrors one real protocol at the granularity of its
//! atomic operations (one mutex-protected critical section or one
//! atomic RMW per step), in a *faithful* variant that must pass and in
//! *seeded-bug* variants reproducing the race the real code guards
//! against — those must be caught, which is what proves the checker has
//! the power to see the bug class at all:
//!
//! 1. **Router outstanding-count accounting under failover**
//!    ([`check_router`]) — the coordinator `Router`'s least-outstanding
//!    routing racing worker completions and a quarantine/deregister.
//!    Invariants: no negative outstanding (the double-complete bug), and
//!    live replicas quiesce to zero outstanding.
//! 2. **Registry epoch-guarded swap vs in-flight resolve**
//!    ([`check_registry`]) — `ModelRegistry::load`'s epoch allocation +
//!    entry swap racing readers resolving entries. Invariants: no
//!    resolve observes a torn entry (epoch and server from different
//!    loads), and the published epoch never regresses (two concurrent
//!    loads must swap in initiation order — the guard the unguarded
//!    variant drops).
//! 3. **Shard retry-budget token accounting** ([`check_budget`]) — the
//!    serving `RetryBudget`'s deposit/withdraw arithmetic. Invariants:
//!    tokens stay within `[0, cap]` and, when the cap never binds,
//!    conserve exactly (the split read-modify-write variant loses
//!    deposits).
//! 4. **Bounded work-stealing past admission-blocked units**
//!    ([`check_steal`]) — the `FrameWorld` scheduler frontier: an XPE
//!    parked on an admission threshold steals short already-admitted
//!    VDPs while a producer drains activations toward its wake.
//!    Invariants: no VDP executes a slice twice (double-steal), a
//!    mid-VDP PCA charge never loses its owner (abandonment), a woken
//!    XPE never claims fresh stolen work (the stall bound that keeps
//!    "pipelined ≤ sequential" provable), no XPE issues its own unit
//!    before its threshold, and no wake-heap entry is orphaned.
//!
//! [`interleave`]: super::interleave

use super::interleave::{Explorer, Report, Shared, Step, Thread};

// ---------------------------------------------------------------------
// 1. Router outstanding-count accounting under failover
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct ReplicaCell {
    pub present: bool,
    pub outstanding: i64,
    pub routed: u64,
    pub completed: u64,
}

#[derive(Debug, Clone)]
pub struct RouterState {
    pub replicas: Vec<ReplicaCell>,
    /// Requests shed because no replica was present at route time.
    pub shed: u64,
}

/// Seeded bugs for [`check_router`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterBug {
    /// The pre-fix coordinator bug: a request completed on both the
    /// submit error path and the worker path — outstanding underflows.
    DoubleComplete,
}

/// One in-flight request: route (least-outstanding among present
/// replicas, atomically incrementing), then complete (atomically
/// decrementing unless the replica was deregistered meanwhile — the
/// real `Router::complete` no-ops on gone replicas).
#[derive(Clone)]
struct Requester {
    pc: u8,
    target: Option<usize>,
    bug: Option<RouterBug>,
}

impl Requester {
    fn complete(&self, s: &mut RouterState) {
        if let Some(r) = self.target {
            if s.replicas[r].present {
                s.replicas[r].outstanding -= 1;
                s.replicas[r].completed += 1;
            }
        }
    }
}

impl Thread<RouterState> for Requester {
    fn step(&mut self, shared: &mut Shared<RouterState>) -> Step {
        match self.pc {
            0 => {
                self.target = shared.with(|s| {
                    let pick = s
                        .replicas
                        .iter()
                        .enumerate()
                        .filter(|(_, r)| r.present)
                        .min_by_key(|(i, r)| (r.outstanding, *i))
                        .map(|(i, _)| i);
                    match pick {
                        Some(i) => {
                            s.replicas[i].outstanding += 1;
                            s.replicas[i].routed += 1;
                        }
                        None => s.shed += 1,
                    }
                    pick
                });
                self.pc = 1;
                if self.target.is_none() {
                    return Step::Done; // shed: nothing to complete
                }
                Step::Ran
            }
            1 => {
                shared.with(|s| self.complete(s));
                self.pc = 2;
                if self.bug == Some(RouterBug::DoubleComplete) {
                    Step::Ran
                } else {
                    Step::Done
                }
            }
            _ => {
                // Seeded bug: the request completes a second time.
                shared.with(|s| self.complete(s));
                Step::Done
            }
        }
    }
    fn boxed_clone(&self) -> Box<dyn Thread<RouterState>> {
        Box::new(self.clone())
    }
}

/// Failover: deregister replica 0 at an arbitrary point.
#[derive(Clone)]
struct Quarantiner;

impl Thread<RouterState> for Quarantiner {
    fn step(&mut self, shared: &mut Shared<RouterState>) -> Step {
        shared.with(|s| s.replicas[0].present = false);
        Step::Done
    }
    fn boxed_clone(&self) -> Box<dyn Thread<RouterState>> {
        Box::new(self.clone())
    }
}

/// Explore `requesters` concurrent requests over `replicas` replicas,
/// optionally racing a quarantine of replica 0.
pub fn check_router(
    explorer: &Explorer,
    requesters: usize,
    replicas: usize,
    quarantine: bool,
    bug: Option<RouterBug>,
) -> Report {
    let init = RouterState {
        replicas: vec![
            ReplicaCell { present: true, outstanding: 0, routed: 0, completed: 0 };
            replicas
        ],
        shed: 0,
    };
    let mut threads: Vec<Box<dyn Thread<RouterState>>> = (0..requesters)
        .map(|_| {
            Box::new(Requester { pc: 0, target: None, bug }) as Box<dyn Thread<RouterState>>
        })
        .collect();
    if quarantine {
        threads.push(Box::new(Quarantiner));
    }
    explorer.explore(init, threads, |s: &RouterState, quiescent| {
        for (i, r) in s.replicas.iter().enumerate() {
            if r.outstanding < 0 {
                return Err(format!(
                    "replica {} outstanding underflowed to {} (double-complete)",
                    i, r.outstanding
                ));
            }
            if r.present && r.outstanding != (r.routed as i64 - r.completed as i64) {
                return Err(format!(
                    "replica {} lost an update: outstanding {} != routed {} - completed {}",
                    i, r.outstanding, r.routed, r.completed
                ));
            }
            if quiescent && r.present && r.outstanding != 0 {
                return Err(format!(
                    "replica {} quiesced with {} outstanding",
                    i, r.outstanding
                ));
            }
        }
        Ok(())
    })
}

// ---------------------------------------------------------------------
// 2. Registry epoch-guarded swap vs in-flight resolve
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Entry {
    pub epoch: u64,
    /// Identity of the server built for this epoch; equals `epoch` in a
    /// consistent entry, so `server != epoch` IS a torn publication.
    pub server: u64,
}

#[derive(Debug, Clone)]
pub struct RegistryState {
    /// The `AtomicU64` epoch counter.
    pub next_epoch: u64,
    /// The entry behind the model name (the `RwLock`-guarded map slot).
    pub published: Entry,
    /// Highest epoch ever published (for the regression check).
    pub max_published: u64,
    /// Set by a reader that resolved an entry whose halves disagree.
    pub torn_observed: bool,
    /// Set at publish time when the published epoch went backwards.
    pub regressed: bool,
}

/// Seeded bugs for [`check_registry`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RegistryBug {
    /// Publish the entry's two halves in two separate shared ops — a
    /// reader between them resolves a torn entry.
    TornEntry,
    /// Drop the epoch guard on the swap: a slower earlier load
    /// overwrites a faster later one, regressing the published epoch.
    UnguardedSwap,
}

/// `ModelRegistry::load`: allocate an epoch (atomic fetch_add), build
/// the server off-lock, then swap the entry under the write lock —
/// guarded so a stale build never overwrites a newer one.
#[derive(Clone)]
struct Loader {
    pc: u8,
    my_epoch: u64,
    bug: Option<RegistryBug>,
}

impl Thread<RegistryState> for Loader {
    fn step(&mut self, shared: &mut Shared<RegistryState>) -> Step {
        match (self.pc, self.bug) {
            (0, _) => {
                self.my_epoch = shared.with(|s| {
                    s.next_epoch += 1;
                    s.next_epoch
                });
                self.pc = 1;
                Step::Ran
            }
            (1, Some(RegistryBug::TornEntry)) => {
                let e = self.my_epoch;
                shared.with(|s| s.published.epoch = e);
                self.pc = 2;
                Step::Ran
            }
            (2, Some(RegistryBug::TornEntry)) => {
                let e = self.my_epoch;
                shared.with(|s| {
                    s.published.server = e;
                    if s.published.epoch < s.max_published {
                        s.regressed = true;
                    }
                    s.max_published = s.max_published.max(s.published.epoch);
                });
                Step::Done
            }
            (1, Some(RegistryBug::UnguardedSwap)) => {
                let e = self.my_epoch;
                shared.with(|s| {
                    s.published = Entry { epoch: e, server: e };
                    if e < s.max_published {
                        s.regressed = true;
                    }
                    s.max_published = s.max_published.max(e);
                });
                Step::Done
            }
            _ => {
                // Faithful: one atomic swap, epoch-guarded.
                let e = self.my_epoch;
                shared.with(|s| {
                    if e > s.published.epoch {
                        s.published = Entry { epoch: e, server: e };
                        if e < s.max_published {
                            s.regressed = true;
                        }
                        s.max_published = s.max_published.max(e);
                    }
                });
                Step::Done
            }
        }
    }
    fn boxed_clone(&self) -> Box<dyn Thread<RegistryState>> {
        Box::new(self.clone())
    }
}

/// A request resolving the entry, then using what it resolved (the
/// `Arc` clone keeps the old server alive, so use always succeeds —
/// what must never happen is observing a torn entry).
#[derive(Clone)]
struct Resolver {
    pc: u8,
    seen: Entry,
}

impl Thread<RegistryState> for Resolver {
    fn step(&mut self, shared: &mut Shared<RegistryState>) -> Step {
        match self.pc {
            0 => {
                self.seen = shared.with(|s| s.published);
                self.pc = 1;
                Step::Ran
            }
            _ => {
                let seen = self.seen;
                shared.with(|s| {
                    if seen.epoch != seen.server {
                        s.torn_observed = true;
                    }
                });
                Step::Done
            }
        }
    }
    fn boxed_clone(&self) -> Box<dyn Thread<RegistryState>> {
        Box::new(self.clone())
    }
}

/// Explore `loaders` concurrent hot-loads of one model name racing
/// `readers` resolves.
pub fn check_registry(
    explorer: &Explorer,
    loaders: usize,
    readers: usize,
    bug: Option<RegistryBug>,
) -> Report {
    let init = RegistryState {
        next_epoch: 0,
        published: Entry { epoch: 0, server: 0 },
        max_published: 0,
        torn_observed: false,
        regressed: false,
    };
    let mut threads: Vec<Box<dyn Thread<RegistryState>>> = Vec::new();
    for _ in 0..loaders {
        threads.push(Box::new(Loader { pc: 0, my_epoch: 0, bug }));
    }
    for _ in 0..readers {
        threads.push(Box::new(Resolver { pc: 0, seen: Entry { epoch: 0, server: 0 } }));
    }
    explorer.explore(init, threads, |s: &RegistryState, quiescent| {
        if s.published.epoch != s.published.server && s.published.epoch != 0 {
            // A torn entry is visible in the state itself between the
            // two halves of a split publication.
            return Err(format!(
                "published entry is torn: epoch {} vs server {}",
                s.published.epoch, s.published.server
            ));
        }
        if s.torn_observed {
            return Err("a resolve observed a torn entry".to_string());
        }
        if s.regressed {
            return Err("published epoch regressed (stale load overwrote newer)".to_string());
        }
        if quiescent && s.published.epoch != s.next_epoch {
            return Err(format!(
                "last-initiated load must win: published {} vs allocated {}",
                s.published.epoch, s.next_epoch
            ));
        }
        Ok(())
    })
}

// ---------------------------------------------------------------------
// 3. Shard retry-budget token accounting
// ---------------------------------------------------------------------

/// Token arithmetic in integer tenths (the real budget uses f64 with a
/// 0.1 deposit ratio; tenths keep the model exact).
#[derive(Debug, Clone)]
pub struct BudgetState {
    pub tokens: i64,
    pub cap: i64,
    pub deposits: u64,
    pub withdrawals: u64,
    pub denials: u64,
}

/// One deposit credits this many tenths (budget_ratio = 0.1 per
/// request, scaled to keep the model integral).
pub const DEPOSIT: i64 = 1;
/// One retry withdraws this many tenths (a whole token).
pub const WITHDRAW: i64 = 10;

/// Seeded bug for [`check_budget`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BudgetBug {
    /// Deposit as read-then-write in two shared ops: concurrent
    /// deposits lose updates.
    SplitRmw,
}

#[derive(Clone)]
struct Depositor {
    left: usize,
    staged: Option<i64>,
    bug: Option<BudgetBug>,
}

impl Thread<BudgetState> for Depositor {
    fn step(&mut self, shared: &mut Shared<BudgetState>) -> Step {
        match (self.bug, self.staged) {
            (Some(BudgetBug::SplitRmw), None) => {
                self.staged = Some(shared.with(|s| s.tokens));
                Step::Ran
            }
            (Some(BudgetBug::SplitRmw), Some(read)) => {
                shared.with(|s| {
                    s.tokens = (read + DEPOSIT).min(s.cap);
                    s.deposits += 1;
                });
                self.staged = None;
                self.left -= 1;
                if self.left == 0 {
                    Step::Done
                } else {
                    Step::Ran
                }
            }
            _ => {
                shared.with(|s| {
                    s.tokens = (s.tokens + DEPOSIT).min(s.cap);
                    s.deposits += 1;
                });
                self.left -= 1;
                if self.left == 0 {
                    Step::Done
                } else {
                    Step::Ran
                }
            }
        }
    }
    fn boxed_clone(&self) -> Box<dyn Thread<BudgetState>> {
        Box::new(self.clone())
    }
}

#[derive(Clone)]
struct Withdrawer {
    left: usize,
}

impl Thread<BudgetState> for Withdrawer {
    fn step(&mut self, shared: &mut Shared<BudgetState>) -> Step {
        shared.with(|s| {
            if s.tokens >= WITHDRAW {
                s.tokens -= WITHDRAW;
                s.withdrawals += 1;
            } else {
                s.denials += 1;
            }
        });
        self.left -= 1;
        if self.left == 0 {
            Step::Done
        } else {
            Step::Ran
        }
    }
    fn boxed_clone(&self) -> Box<dyn Thread<BudgetState>> {
        Box::new(self.clone())
    }
}

/// Explore depositors (each making `deposits_each` deposits) racing
/// withdrawers (each attempting `withdraws_each` withdrawals) over a
/// budget starting at `initial` tenths. Pass a `cap` high enough that
/// clamping never binds and conservation is checked exactly.
#[allow(clippy::too_many_arguments)]
pub fn check_budget(
    explorer: &Explorer,
    depositors: usize,
    deposits_each: usize,
    withdrawers: usize,
    withdraws_each: usize,
    initial: i64,
    cap: i64,
    bug: Option<BudgetBug>,
) -> Report {
    let init = BudgetState { tokens: initial, cap, deposits: 0, withdrawals: 0, denials: 0 };
    let mut threads: Vec<Box<dyn Thread<BudgetState>>> = Vec::new();
    if deposits_each > 0 {
        for _ in 0..depositors {
            threads.push(Box::new(Depositor { left: deposits_each, staged: None, bug }));
        }
    }
    if withdraws_each > 0 {
        for _ in 0..withdrawers {
            threads.push(Box::new(Withdrawer { left: withdraws_each }));
        }
    }
    let cap_can_bind = initial + (depositors * deposits_each) as i64 * DEPOSIT > cap;
    explorer.explore(init, threads, move |s: &BudgetState, quiescent| {
        if s.tokens < 0 {
            return Err(format!("tokens underflowed to {}", s.tokens));
        }
        if s.tokens > s.cap {
            return Err(format!("tokens {} exceed the cap {}", s.tokens, s.cap));
        }
        if quiescent && !cap_can_bind {
            let expect = initial + s.deposits as i64 * DEPOSIT - s.withdrawals as i64 * WITHDRAW;
            if s.tokens != expect {
                return Err(format!(
                    "lost update: {} tokens after {} deposits / {} withdrawals (expected {})",
                    s.tokens, s.deposits, s.withdrawals, expect
                ));
            }
        }
        Ok(())
    })
}

// ---------------------------------------------------------------------
// 4. Bounded work-stealing past admission-blocked units
// ---------------------------------------------------------------------

/// One stealable side VDP: `slices` passes of closed-form remaining
/// cost, `done` of them executed, locked to the claiming stealer while
/// mid-VDP (the PcaLocal accumulation charge that must not change
/// hands).
#[derive(Debug, Clone)]
pub struct StealUnit {
    pub slices: usize,
    pub done: usize,
    pub claimed: Option<usize>,
}

/// Shared scheduler state: one producer draining `acts_done` toward the
/// stealers' admission thresholds, the wake index (`registered` /
/// `woken` per stealer, mirroring the threshold heap), the stealable
/// side units, and per-stealer steal budgets (the expected-stall bound
/// in pass slots).
#[derive(Debug, Clone)]
pub struct StealState {
    pub acts_done: usize,
    /// Admission threshold of each stealer's own (consumer) unit.
    pub need: Vec<usize>,
    /// Wake-heap entry live (registered at park, popped at wake).
    pub registered: Vec<bool>,
    /// Wake delivered: the stealer's threshold has been crossed.
    pub woken: Vec<bool>,
    /// Remaining steal budget per stealer, in slices.
    pub budget: Vec<usize>,
    pub units: Vec<StealUnit>,
    /// Producer count observed when each stealer issued its own unit.
    pub own_issued_at: Vec<Option<usize>>,
    /// Set when a stealer claimed a fresh unit after its wake.
    pub claim_after_wake: bool,
    /// Stolen slices executed in total.
    pub stolen: u64,
    /// Wakes delivered by the producer's drain loop.
    pub wakes: u64,
}

/// Seeded bugs for [`check_steal`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StealBug {
    /// Split the claim into a read step and a write step: two parked
    /// XPEs claim the same VDP and its slices execute twice.
    DoubleSteal,
    /// Ignore the wake when choosing the next steal: a woken XPE keeps
    /// claiming fresh work, stretching its stall past the closed-form
    /// bound that keeps "pipelined ≤ sequential" provable.
    StealPastWake,
    /// Abandon a stolen VDP's remaining slices on wake: the mid-VDP
    /// PCA charge is left with no owner.
    MidVdpAbandon,
}

/// First side unit stealer `k` may claim under its remaining budget.
fn steal_eligible(s: &StealState, k: usize) -> Option<usize> {
    s.units
        .iter()
        .position(|u| u.claimed.is_none() && u.done < u.slices && u.slices - u.done <= s.budget[k])
}

/// The producer: drains one activation per step and, atomically with
/// the drain, pops every waiter whose threshold the new count crosses —
/// exactly the shape of the real `ActivationDone` handler over the
/// PR-5 wake heap.
#[derive(Clone)]
struct Drainer {
    left: usize,
}

impl Thread<StealState> for Drainer {
    fn step(&mut self, shared: &mut Shared<StealState>) -> Step {
        shared.with(|s| {
            s.acts_done += 1;
            for k in 0..s.registered.len() {
                if s.registered[k] && s.acts_done >= s.need[k] {
                    s.registered[k] = false;
                    s.woken[k] = true;
                    s.wakes += 1;
                }
            }
        });
        self.left -= 1;
        if self.left == 0 {
            Step::Done
        } else {
            Step::Ran
        }
    }
    fn boxed_clone(&self) -> Box<dyn Thread<StealState>> {
        Box::new(self.clone())
    }
}

/// What a stealer decided in one atomic scheduler op.
enum StealNext {
    Own,
    Claimed(usize),
    Wait,
}

/// An XPE parked on an admission threshold. Faithful protocol: park
/// with an atomic check-then-register (pc 0); then loop — claim an
/// eligible side unit atomically or return to its own unit once woken
/// (pc 1); execute a stolen VDP to completion, one slice per step,
/// even if the wake lands mid-VDP (pc 2); finally issue its own unit
/// (pc 3).
#[derive(Clone)]
struct Stealer {
    k: usize,
    pc: u8,
    unit: usize,
    /// DoubleSteal only: unit picked in the split claim's read phase.
    pending: Option<usize>,
    bug: Option<StealBug>,
}

impl Thread<StealState> for Stealer {
    fn step(&mut self, shared: &mut Shared<StealState>) -> Step {
        let k = self.k;
        match self.pc {
            0 => {
                // Park: threshold check and waiter registration are ONE
                // op (the real dispatch() runs inside a single event
                // handler), so the wake can never be lost between them.
                shared.with(|s| {
                    if s.acts_done >= s.need[k] {
                        s.woken[k] = true; // admitted immediately: no park
                    } else {
                        s.registered[k] = true;
                    }
                });
                self.pc = 1;
                Step::Ran
            }
            1 if self.bug == Some(StealBug::DoubleSteal) => {
                if let Some(u) = self.pending {
                    // Write phase of the split claim: claim blindly —
                    // the unit may have been claimed since the read.
                    // (The read phase already honored the wake, so only
                    // the double-execution class is seeded here.)
                    shared.with(|s| {
                        let rem = s.units[u].slices.saturating_sub(s.units[u].done);
                        s.units[u].claimed = Some(k);
                        s.budget[k] = s.budget[k].saturating_sub(rem);
                    });
                    self.pending = None;
                    self.unit = u;
                    self.pc = 2;
                    return Step::Ran;
                }
                // Read phase: pick a unit without claiming it.
                let next = shared.with(|s| {
                    if s.woken[k] {
                        StealNext::Own
                    } else {
                        match steal_eligible(s, k) {
                            Some(u) => StealNext::Claimed(u),
                            None => StealNext::Wait,
                        }
                    }
                });
                match next {
                    StealNext::Own => {
                        self.pc = 3;
                        Step::Ran
                    }
                    StealNext::Claimed(u) => {
                        self.pending = Some(u);
                        Step::Ran
                    }
                    StealNext::Wait => Step::Blocked,
                }
            }
            1 => {
                // Faithful claim-or-return, one atomic op. StealPastWake
                // drops the woken check and keeps claiming.
                let past_wake = self.bug == Some(StealBug::StealPastWake);
                let next = shared.with(|s| {
                    if !past_wake && s.woken[k] {
                        return StealNext::Own;
                    }
                    match steal_eligible(s, k) {
                        Some(u) => {
                            let rem = s.units[u].slices - s.units[u].done;
                            s.units[u].claimed = Some(k);
                            s.budget[k] -= rem;
                            if s.woken[k] {
                                s.claim_after_wake = true;
                            }
                            StealNext::Claimed(u)
                        }
                        None if s.woken[k] => StealNext::Own,
                        None => StealNext::Wait,
                    }
                });
                match next {
                    StealNext::Own => {
                        self.pc = 3;
                        Step::Ran
                    }
                    StealNext::Claimed(u) => {
                        self.unit = u;
                        self.pc = 2;
                        Step::Ran
                    }
                    StealNext::Wait => Step::Blocked,
                }
            }
            2 => {
                // Execute one stolen slice. Faithful: run the VDP to
                // completion even if woken mid-flight; MidVdpAbandon
                // drops it on wake instead.
                let abandon = self.bug == Some(StealBug::MidVdpAbandon);
                let u = self.unit;
                let finished = shared.with(|s| {
                    if abandon && s.woken[k] && s.units[u].done < s.units[u].slices {
                        s.units[u].claimed = None;
                        return None; // abandoned mid-VDP
                    }
                    s.units[u].done += 1;
                    s.stolen += 1;
                    if s.units[u].done >= s.units[u].slices {
                        s.units[u].claimed = None;
                        Some(true)
                    } else {
                        Some(false)
                    }
                });
                match finished {
                    None => {
                        self.pc = 3;
                        Step::Ran
                    }
                    Some(true) => {
                        self.pc = 1;
                        Step::Ran
                    }
                    Some(false) => Step::Ran,
                }
            }
            _ => {
                // Issue the own (consumer) unit, recording the producer
                // count it was admitted at.
                shared.with(|s| s.own_issued_at[k] = Some(s.acts_done));
                Step::Done
            }
        }
    }
    fn boxed_clone(&self) -> Box<dyn Thread<StealState>> {
        Box::new(self.clone())
    }
}

/// Explore one producer draining `acts_total` activations racing one
/// parked stealer per entry of `needs` (its admission threshold), over
/// side units of the given slice counts, each stealer holding `budget`
/// slices of steal headroom.
pub fn check_steal(
    explorer: &Explorer,
    needs: &[usize],
    acts_total: usize,
    unit_slices: &[usize],
    budget: usize,
    bug: Option<StealBug>,
) -> Report {
    assert!(
        needs.iter().all(|&n| n <= acts_total),
        "producer must drain past every threshold or the park never wakes"
    );
    let stealers = needs.len();
    let init = StealState {
        acts_done: 0,
        need: needs.to_vec(),
        registered: vec![false; stealers],
        woken: vec![false; stealers],
        budget: vec![budget; stealers],
        units: unit_slices
            .iter()
            .map(|&slices| StealUnit { slices, done: 0, claimed: None })
            .collect(),
        own_issued_at: vec![None; stealers],
        claim_after_wake: false,
        stolen: 0,
        wakes: 0,
    };
    let mut threads: Vec<Box<dyn Thread<StealState>>> =
        vec![Box::new(Drainer { left: acts_total })];
    for k in 0..stealers {
        threads.push(Box::new(Stealer { k, pc: 0, unit: 0, pending: None, bug }));
    }
    explorer.explore(init, threads, |s: &StealState, quiescent| {
        for (i, u) in s.units.iter().enumerate() {
            if u.done > u.slices {
                return Err(format!(
                    "unit {} executed {} of {} slices (double-steal)",
                    i, u.done, u.slices
                ));
            }
            if u.done > 0 && u.done < u.slices && u.claimed.is_none() {
                return Err(format!(
                    "unit {} abandoned mid-VDP at {}/{} slices with no owner",
                    i, u.done, u.slices
                ));
            }
            if quiescent && u.done != 0 && u.done != u.slices {
                return Err(format!(
                    "unit {} quiesced mid-VDP at {}/{} slices",
                    i, u.done, u.slices
                ));
            }
        }
        if s.claim_after_wake {
            return Err(
                "a woken stealer claimed fresh work (steal past wake breaks the stall bound)"
                    .to_string(),
            );
        }
        for (k, issued) in s.own_issued_at.iter().enumerate() {
            if let Some(acts) = issued {
                if *acts < s.need[k] {
                    return Err(format!(
                        "stealer {} issued its own unit at {} acts < threshold {}",
                        k, acts, s.need[k]
                    ));
                }
            }
        }
        if quiescent {
            for k in 0..s.need.len() {
                if s.registered[k] {
                    return Err(format!(
                        "stealer {} quiesced with a live wake-heap entry (orphaned waiter)",
                        k
                    ));
                }
                if s.own_issued_at[k].is_none() {
                    return Err(format!("stealer {} never issued its own unit", k));
                }
            }
        }
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast() -> Explorer {
        Explorer { max_preemptions: usize::MAX, max_schedules: 50_000 }
    }

    #[test]
    fn faithful_protocols_pass_small_configs() {
        check_router(&fast(), 2, 2, true, None).assert_clean();
        check_registry(&fast(), 2, 2, None).assert_clean();
        check_budget(&fast(), 2, 1, 1, 1, 10, 1000, None).assert_clean();
        check_steal(&fast(), &[2], 2, &[2, 1], 4, None).assert_clean();
    }

    #[test]
    fn seeded_bugs_are_caught() {
        assert!(
            check_router(&fast(), 2, 2, true, Some(RouterBug::DoubleComplete))
                .violation
                .is_some(),
            "double-complete must underflow outstanding"
        );
        assert!(
            check_registry(&fast(), 2, 2, Some(RegistryBug::TornEntry))
                .violation
                .is_some(),
            "split publication must be observed torn"
        );
        assert!(
            check_registry(&fast(), 2, 1, Some(RegistryBug::UnguardedSwap))
                .violation
                .is_some(),
            "unguarded swap must regress the epoch"
        );
        assert!(
            check_budget(&fast(), 2, 1, 0, 0, 0, 1000, Some(BudgetBug::SplitRmw))
                .violation
                .is_some(),
            "split RMW must lose a deposit"
        );
        assert!(
            check_steal(&fast(), &[2, 2], 2, &[1], 4, Some(StealBug::DoubleSteal))
                .violation
                .is_some(),
            "a split claim must execute the same VDP twice"
        );
        assert!(
            check_steal(&fast(), &[1], 1, &[1, 1], 4, Some(StealBug::StealPastWake))
                .violation
                .is_some(),
            "claiming past the wake must break the stall bound"
        );
        assert!(
            check_steal(&fast(), &[1], 1, &[2], 4, Some(StealBug::MidVdpAbandon))
                .violation
                .is_some(),
            "abandoning a stolen VDP mid-flight must orphan the PCA charge"
        );
    }
}
