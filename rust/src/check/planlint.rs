//! Static plan verifier: proves, without running the simulator, that a
//! compiled [`ExecutionPlan`] / [`FramePlan`] can execute legally.
//!
//! Everything the event simulator trusts at runtime is re-derived here
//! *independently* of the plan code and cross-checked:
//!
//! * **Admission** — the cross-layer dependency graph is deadlock-free:
//!   unit producer edges are acyclic (Kahn), every head-pass threshold is
//!   producible by the producer's raster order (the unclamped
//!   receptive-field reach never exceeds the producer's activation
//!   count), and the runtime rule [`FramePlan::need_acts`] agrees with
//!   the linter's own closed-form re-derivation at every output position.
//! * **Conservation** — per-XPE pass maps sum to the closed-form totals,
//!   the declared critical path really is the longest queue, and the
//!   slice table tiles the vector size exactly.
//! * **Capacity** — PCA accumulation never exceeds the accelerator's
//!   `B_PCA` bound `γ` (paper Section III-B2) for the configured mapping
//!   policy, and `γ` itself agrees with the paper-calibrated Table II
//!   value for the configured data rate.
//! * **Balance** — neither mapping policy over- or under-subscribes an
//!   XPE beyond its balance bound (`slices` for `PcaLocal`, 1 for
//!   `SlicedSpread`), and the pass map spans exactly the hardware grid.
//!
//! Findings carry a fixed [`Severity`] and a machine-readable [`Code`]
//! (`PL1xx` mapping, `PL2xx` admission, `PL3xx` capacity). Only
//! [`Severity::Error`] findings make a plan unservable — the CLI `lint`
//! subcommand exits non-zero on them and the serving registry refuses
//! the model load ([`LintRejection`], surfaced as HTTP 422).
//!
//! [`FramePlan::need_acts`]: crate::plan::FramePlan::need_acts

use std::fmt;

use crate::arch::accelerator::BitcountMode;
use crate::mapping::layer::{ConvGeom, GemmLayer};
use crate::mapping::scheduler::MappingPolicy;
use crate::plan::{AdmissionMode, ExecutionPlan, FramePlan, LayerPlan, ShardPlan, ShardPolicy};

/// How bad a finding is. Only `Error` findings fail the lint gate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Expected but worth surfacing (e.g. an FC layer's whole-map wait).
    Info,
    /// Legal but suspicious or performance-degrading (e.g. a conv whose
    /// geometry does not chain, losing cross-layer pipelining).
    Warning,
    /// The plan cannot execute correctly; the gate refuses it.
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Severity::Info => "info",
            Severity::Warning => "warning",
            Severity::Error => "error",
        })
    }
}

/// Machine-readable finding codes. The numeric id is stable — tests, CI
/// logs and API clients may match on it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Code {
    /// PL101: `plan.layers[i].layer` disagrees with `workload.layers[i]`
    /// (the two views [`ExecutionPlan`] promises identical).
    ViewMismatch,
    /// PL102: a layer was compiled for a different XPE geometry (N or
    /// XPC shape) than the plan's accelerator provides.
    GridMismatch,
    /// PL103: per-XPE queue lengths do not conserve the closed-form pass
    /// total, or the declared critical path is not the longest queue.
    PassCountMismatch,
    /// PL104: the slice table does not tile the vector size `S` into
    /// `ceil(S/N)` slices of length `1..=N`.
    SliceTableCorrupt,
    /// PL105: the pass map spans more (or fewer) XPE slots than the
    /// accelerator physically has — passes would land on XPEs that do
    /// not exist, or leave hardware permanently idle.
    XpeOversubscribed,
    /// PL106: queue-length spread exceeds the mapping policy's balance
    /// bound (`slices` for `PcaLocal`, 1 for `SlicedSpread`).
    XpeImbalance,
    /// PL201: the unit dependency graph has a cycle (or a producer edge
    /// pointing forward in frame-major order) — admission deadlock.
    AdmissionCycle,
    /// PL202: an admission threshold exceeds what the producer will ever
    /// drain — the consumer would wait forever.
    AdmissionUnsatisfiable,
    /// PL203: a layer's [`ConvGeom`] violates its own invariants
    /// (degenerate sides, padding ≥ kernel, kernel off the padded map).
    GeomInvalid,
    /// PL204: the [`ConvGeom`] is inconsistent with the GEMM flattening
    /// it claims to describe (output map does not divide the VDP count,
    /// or `S` disagrees with `kernel² × producer channels`).
    GeomGemmMismatch,
    /// PL205: a conv-shaped consumer falls back to the whole-map wait
    /// (no geometry, or geometry that does not chain onto the producer's
    /// output map) — sound, but cross-layer pipelining is lost.
    AdmissionFallback,
    /// PL206: the runtime rule [`FramePlan::need_acts`] disagrees with
    /// the linter's independent re-derivation of the same threshold.
    ///
    /// [`FramePlan::need_acts`]: crate::plan::FramePlan::need_acts
    AdmissionDrift,
    /// PL301: a PASS would accumulate more '1's than the PCA capacity
    /// `γ` can hold (paper Section III-B2: functional-error territory).
    PcaOverflow,
    /// PL302: the configured `γ` drifts from the paper-calibrated
    /// Table II value for the accelerator's data rate.
    PcaCapacityDrift,
    /// PL401: a shard group's stage map does not cover the model — a
    /// layer is assigned to a chip outside the group, the stage map's
    /// length disagrees with the layer count, or the compiled grid does
    /// not span `chips × T` XPE slots under VdpSplit.
    ShardCoverage,
    /// PL402: a LayerPipeline stage map is not a contiguous,
    /// non-decreasing partition starting on chip 0 — stages would
    /// interleave (two chips claiming overlapping layer ranges) and the
    /// inter-chip transfer accounting breaks.
    ShardOverlap,
    /// PL403: the inter-chip transfer channel is degenerate (non-positive
    /// bandwidth, zero-bit activations, negative or non-finite latency) —
    /// cross-chip activations could never arrive.
    LinkCapacity,
    /// PL404: the shard group is poorly balanced — the bottleneck stage
    /// dominates the mean stage time, or the serialized transfer channel
    /// is slower than the bottleneck stage it feeds (the link, not the
    /// chips, sets the streaming rate).
    ShardImbalance,
}

impl Code {
    /// Stable numeric id, e.g. `"PL301"`.
    pub fn id(&self) -> &'static str {
        match self {
            Code::ViewMismatch => "PL101",
            Code::GridMismatch => "PL102",
            Code::PassCountMismatch => "PL103",
            Code::SliceTableCorrupt => "PL104",
            Code::XpeOversubscribed => "PL105",
            Code::XpeImbalance => "PL106",
            Code::AdmissionCycle => "PL201",
            Code::AdmissionUnsatisfiable => "PL202",
            Code::GeomInvalid => "PL203",
            Code::GeomGemmMismatch => "PL204",
            Code::AdmissionFallback => "PL205",
            Code::AdmissionDrift => "PL206",
            Code::PcaOverflow => "PL301",
            Code::PcaCapacityDrift => "PL302",
            Code::ShardCoverage => "PL401",
            Code::ShardOverlap => "PL402",
            Code::LinkCapacity => "PL403",
            Code::ShardImbalance => "PL404",
        }
    }

    /// The fixed severity of this code.
    pub fn severity(&self) -> Severity {
        match self {
            Code::ViewMismatch
            | Code::GridMismatch
            | Code::PassCountMismatch
            | Code::SliceTableCorrupt
            | Code::XpeOversubscribed
            | Code::XpeImbalance
            | Code::AdmissionCycle
            | Code::AdmissionUnsatisfiable
            | Code::GeomInvalid
            | Code::GeomGemmMismatch
            | Code::AdmissionDrift
            | Code::PcaOverflow
            | Code::ShardCoverage
            | Code::ShardOverlap
            | Code::LinkCapacity => Severity::Error,
            Code::PcaCapacityDrift | Code::ShardImbalance => Severity::Warning,
            Code::AdmissionFallback => Severity::Info,
        }
    }
}

impl fmt::Display for Code {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.id())
    }
}

/// One lint finding: code + severity + where + why.
#[derive(Debug, Clone)]
pub struct Finding {
    pub code: Code,
    pub severity: Severity,
    /// Workload layer index the finding anchors to, when layer-scoped.
    pub layer: Option<usize>,
    pub message: String,
}

impl Finding {
    fn new(code: Code, layer: Option<usize>, message: String) -> Finding {
        Finding { code, severity: code.severity(), layer, message }
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} [{}]", self.code, self.severity)?;
        if let Some(l) = self.layer {
            write!(f, " layer {}", l)?;
        }
        write!(f, ": {}", self.message)
    }
}

/// True if any finding is [`Severity::Error`].
pub fn has_errors(findings: &[Finding]) -> bool {
    findings.iter().any(|f| f.severity == Severity::Error)
}

/// A plan refused by the lint gate: carries every finding so callers
/// (the serving registry, HTTP 422 bodies) can report precisely.
#[derive(Debug)]
pub struct LintRejection {
    /// What was being linted (model or workload name).
    pub subject: String,
    pub findings: Vec<Finding>,
}

impl fmt::Display for LintRejection {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let errors: Vec<String> = self
            .findings
            .iter()
            .filter(|x| x.severity == Severity::Error)
            .map(|x| x.to_string())
            .collect();
        write!(f, "plan for '{}' failed lint: {}", self.subject, errors.join("; "))
    }
}

impl std::error::Error for LintRejection {}

/// Lint `plan` and refuse it (with every finding attached) if any
/// [`Severity::Error`] finding surfaces — the serving registry's load
/// gate. On success the non-fatal findings are returned for logging.
pub fn gate(subject: &str, plan: &ExecutionPlan) -> Result<Vec<Finding>, LintRejection> {
    let findings = verify(plan);
    if has_errors(&findings) {
        Err(LintRejection { subject: subject.to_string(), findings })
    } else {
        Ok(findings)
    }
}

/// [`gate`] for a multi-chip [`ShardPlan`]: the inner plan must pass the
/// full single-group lint AND the shard geometry checks of
/// [`verify_shard`]. The serving registry routes every K-chip load
/// through here exactly as single-chip loads go through [`gate`].
pub fn gate_shard(subject: &str, shard: &ShardPlan) -> Result<Vec<Finding>, LintRejection> {
    let findings = verify_shard(shard);
    if has_errors(&findings) {
        Err(LintRejection { subject: subject.to_string(), findings })
    } else {
        Ok(findings)
    }
}

/// Verify a multi-chip [`ShardPlan`]: the inner [`ExecutionPlan`] runs
/// the whole single-plan lint (its accelerator is the scaled group grid
/// under VdpSplit, so the grid checks cover the group shape), then the
/// shard geometry is checked on top — stage coverage/contiguity
/// (PL401/PL402), transfer-channel sanity (PL403) and group balance
/// (PL404).
pub fn verify_shard(shard: &ShardPlan) -> Vec<Finding> {
    let mut findings = verify(&shard.plan);
    check_shard_geometry(shard, &mut findings);
    findings
}

/// Verify `plan` under the default (receptive-field-exact) admission
/// mode: per-layer mapping/capacity checks plus the cross-layer
/// admission argument of [`verify_frame`].
pub fn verify(plan: &ExecutionPlan) -> Vec<Finding> {
    verify_with(plan, AdmissionMode::Exact)
}

/// [`verify`] under an explicit [`AdmissionMode`].
pub fn verify_with(plan: &ExecutionPlan, admission: AdmissionMode) -> Vec<Finding> {
    let mut findings = Vec::new();
    if plan.layers.len() != plan.workload.layers.len() {
        findings.push(Finding::new(
            Code::ViewMismatch,
            None,
            format!(
                "plan has {} compiled layers but the workload view has {}",
                plan.layers.len(),
                plan.workload.layers.len()
            ),
        ));
    }
    for (i, lp) in plan.layers.iter().enumerate() {
        check_layer(plan, i, lp, &mut findings);
    }
    check_pca_calibration(plan, &mut findings);
    // Two frames so the frame-major unit numbering (including the
    // frame-boundary "no producer" edge) is exercised, not just frame 0.
    let fp = FramePlan::with_admission(plan, 2, admission);
    findings.extend(verify_frame(&fp));
    findings
}

/// Cross-layer admission checks over an assembled [`FramePlan`]: cycle
/// detection over the unit dependency DAG, producibility of every
/// admission threshold, and agreement between the runtime rule and the
/// linter's independent re-derivation.
pub fn verify_frame(fp: &FramePlan<'_>) -> Vec<Finding> {
    let mut findings = Vec::new();
    check_unit_dag(fp, &mut findings);
    let layers = fp.layers();
    // Admission thresholds are identical across frames (same compiled
    // layers), so scanning frame 0's units covers the whole batch.
    for unit in 0..layers.min(fp.units()) {
        check_admission(fp, unit, &mut findings);
    }
    findings
}

// ---------------------------------------------------------------------
// Per-layer mapping / capacity checks
// ---------------------------------------------------------------------

fn check_layer(plan: &ExecutionPlan, i: usize, lp: &LayerPlan, findings: &mut Vec<Finding>) {
    let acc = &plan.accelerator;
    if let Some(view) = plan.workload.layers.get(i) {
        if *view != lp.layer {
            findings.push(Finding::new(
                Code::ViewMismatch,
                Some(i),
                format!(
                    "compiled layer '{}' disagrees with workload view '{}'",
                    lp.layer.name, view.name
                ),
            ));
        }
    }
    if lp.n != acc.n {
        findings.push(Finding::new(
            Code::GridMismatch,
            Some(i),
            format!("layer sliced for N={} on an N={} accelerator", lp.n, acc.n),
        ));
    }
    if lp.m == 0 || lp.xpc_count == 0 {
        findings.push(Finding::new(
            Code::XpeOversubscribed,
            Some(i),
            "pass map spans zero XPE slots".to_string(),
        ));
        return; // queue-length math divides by the slot count
    }
    let hw_slots = acc.m() * acc.xpc_count();
    if lp.total_xpes() != hw_slots {
        findings.push(Finding::new(
            Code::XpeOversubscribed,
            Some(i),
            format!(
                "pass map spans {} XPE slots but the accelerator grid has {}",
                lp.total_xpes(),
                hw_slots
            ),
        ));
    } else if (lp.m, lp.xpc_count) != (acc.m(), acc.xpc_count()) {
        findings.push(Finding::new(
            Code::GridMismatch,
            Some(i),
            format!(
                "pass map shaped {}x{} XPEs/XPC vs the accelerator's {}x{}",
                lp.xpc_count,
                lp.m,
                acc.xpc_count(),
                acc.m()
            ),
        ));
    }
    check_slice_table(i, lp, findings);
    check_conservation(i, lp, findings);
    check_pca_capacity(acc, i, lp, findings);
    if let Some(geom) = lp.layer.geom {
        check_geom(i, &lp.layer, geom, findings);
    }
}

/// The slice table must tile `S` exactly: `ceil(S/N)` slices, each
/// `1..=N` long, summing to `S`. Read back through [`LayerPlan::pass_at`]
/// (VDP 0's slices, in order, under either policy).
fn check_slice_table(i: usize, lp: &LayerPlan, findings: &mut Vec<Finding>) {
    if lp.n == 0 {
        return; // already a GridMismatch; ceil(S/0) is meaningless
    }
    let slices = lp.slices();
    if slices != lp.layer.s.div_ceil(lp.n) {
        findings.push(Finding::new(
            Code::SliceTableCorrupt,
            Some(i),
            format!(
                "{} slices for S={} on N={} (expected ceil(S/N)={})",
                slices,
                lp.layer.s,
                lp.n,
                lp.layer.s.div_ceil(lp.n)
            ),
        ));
        return;
    }
    let t = lp.total_xpes();
    let mut sum = 0usize;
    for j in 0..slices {
        // VDP 0's j-th slice: PcaLocal keeps it on XPE 0 at queue depth
        // j; SlicedSpread places global slice j on XPE j % T at depth
        // j / T.
        let pass = match lp.policy {
            MappingPolicy::PcaLocal => lp.pass_at(0, j),
            MappingPolicy::SlicedSpread => lp.pass_at(j % t, j / t),
        };
        let Some(pass) = pass else {
            findings.push(Finding::new(
                Code::SliceTableCorrupt,
                Some(i),
                format!("slice {} of VDP 0 is unreachable through the pass map", j),
            ));
            return;
        };
        if pass.slice_len == 0 || pass.slice_len > lp.n {
            findings.push(Finding::new(
                Code::SliceTableCorrupt,
                Some(i),
                format!("slice {} has length {} outside 1..=N={}", j, pass.slice_len, lp.n),
            ));
            return;
        }
        sum += pass.slice_len;
    }
    if sum != lp.layer.s {
        findings.push(Finding::new(
            Code::SliceTableCorrupt,
            Some(i),
            format!("slice lengths sum to {} but the vector size is {}", sum, lp.layer.s),
        ));
    }
}

/// Queue lengths conserve the pass total, the declared critical path is
/// the longest queue, and the spread respects the policy balance bound.
fn check_conservation(i: usize, lp: &LayerPlan, findings: &mut Vec<Finding>) {
    let t = lp.total_xpes();
    let (mut sum, mut max, mut min) = (0usize, 0usize, usize::MAX);
    for x in 0..t {
        let q = lp.queue_len(x);
        sum += q;
        max = max.max(q);
        min = min.min(q);
    }
    if sum != lp.total_passes() {
        findings.push(Finding::new(
            Code::PassCountMismatch,
            Some(i),
            format!(
                "per-XPE queues hold {} passes but the closed form says {} (VDPs {} x slices {})",
                sum,
                lp.total_passes(),
                lp.vdp_count(),
                lp.slices()
            ),
        ));
    }
    if max != lp.max_queue_len() {
        findings.push(Finding::new(
            Code::PassCountMismatch,
            Some(i),
            format!(
                "declared critical path {} but the longest queue is {}",
                lp.max_queue_len(),
                max
            ),
        ));
    }
    let bound = match lp.policy {
        MappingPolicy::PcaLocal => lp.slices(),
        MappingPolicy::SlicedSpread => 1,
    };
    if max.saturating_sub(min) > bound {
        findings.push(Finding::new(
            Code::XpeImbalance,
            Some(i),
            format!(
                "queue spread {} (max {} / min {}) exceeds the {:?} balance bound {}",
                max - min,
                max,
                min,
                lp.policy,
                bound
            ),
        ));
    }
}

/// Worst-case '1's accumulated before a PCA readout must fit `γ`: a full
/// vector under `PcaLocal` (slices accumulate back-to-back in the analog
/// domain), a single slice under `SlicedSpread`.
fn check_pca_capacity(
    acc: &crate::arch::accelerator::AcceleratorConfig,
    i: usize,
    lp: &LayerPlan,
    findings: &mut Vec<Finding>,
) {
    let BitcountMode::Pca { gamma } = &acc.bitcount else {
        return;
    };
    let gamma = *gamma;
    let worst = match lp.policy {
        MappingPolicy::PcaLocal => lp.layer.s as u64,
        MappingPolicy::SlicedSpread => lp.n as u64,
    };
    if worst > gamma {
        findings.push(Finding::new(
            Code::PcaOverflow,
            Some(i),
            format!(
                "layer '{}' accumulates up to {} ones per readout under {:?} but B_PCA={}",
                lp.layer.name, worst, lp.policy, gamma
            ),
        ));
    }
}

/// `γ` itself must match the paper-calibrated Table II value for the
/// accelerator's data rate (0.5% tolerance for interpolated rates).
fn check_pca_calibration(plan: &ExecutionPlan, findings: &mut Vec<Finding>) {
    let acc = &plan.accelerator;
    let BitcountMode::Pca { gamma } = &acc.bitcount else {
        return;
    };
    let gamma = *gamma;
    let calibrated = crate::analysis::pca_capacity::gamma_calibrated(acc.dr_gsps);
    let drift = (gamma as f64 - calibrated as f64).abs() / calibrated as f64;
    if drift > 0.005 {
        findings.push(Finding::new(
            Code::PcaCapacityDrift,
            None,
            format!(
                "configured gamma={} but Table II calibration at {} GS/s gives {}",
                gamma, acc.dr_gsps, calibrated
            ),
        ));
    }
}

/// Re-validate a [`ConvGeom`] without panicking, then check it against
/// the GEMM flattening it claims to describe.
fn check_geom(i: usize, layer: &GemmLayer, g: ConvGeom, findings: &mut Vec<Finding>) {
    if g.kernel == 0 || g.stride == 0 || g.in_hw == 0 {
        findings.push(Finding::new(
            Code::GeomInvalid,
            Some(i),
            format!("degenerate geometry {:?}", g),
        ));
        return;
    }
    if g.padding >= g.kernel {
        findings.push(Finding::new(
            Code::GeomInvalid,
            Some(i),
            format!("padding {} >= kernel {} (windows off the map)", g.padding, g.kernel),
        ));
        return;
    }
    if g.in_hw + 2 * g.padding < g.kernel {
        findings.push(Finding::new(
            Code::GeomInvalid,
            Some(i),
            format!("kernel {} larger than the padded {}-side map", g.kernel, g.in_hw),
        ));
        return;
    }
    let out = g.out_hw();
    let positions = out * out;
    if positions == 0 || layer.vdp_count() % positions != 0 {
        findings.push(Finding::new(
            Code::GeomGemmMismatch,
            Some(i),
            format!(
                "{} VDPs cannot raster the {}x{} output map the geometry implies",
                layer.vdp_count(),
                out,
                out
            ),
        ));
        return;
    }
    // Depthwise position-major flattening: one VDP per (position,
    // channel) with K = 1 — each VDP reads a single k×k window, so the
    // vector size must be exactly kernel².
    let per_pos = layer.vdp_count() / positions;
    if layer.k == 1 && per_pos > 1 && layer.s != g.kernel * g.kernel {
        findings.push(Finding::new(
            Code::GeomGemmMismatch,
            Some(i),
            format!(
                "depthwise vector size {} != kernel^2 = {}",
                layer.s,
                g.kernel * g.kernel
            ),
        ));
    }
}

// ---------------------------------------------------------------------
// Shard geometry checks
// ---------------------------------------------------------------------

/// The PL4xx family: stage coverage and contiguity, transfer-channel
/// sanity, and group balance. Deliberately re-derived from the raw
/// `chip_of_layer` map and link parameters — not from the shard plan's
/// own `edge_crosses`/`stage_times_s` helpers alone — so a corrupted
/// stage map cannot vouch for itself.
fn check_shard_geometry(shard: &ShardPlan, findings: &mut Vec<Finding>) {
    let chips = shard.chips();
    let layers = shard.plan.layers.len();
    match shard.policy() {
        ShardPolicy::VdpSplit => {
            if !shard.chip_of_layer.is_empty() {
                findings.push(Finding::new(
                    Code::ShardCoverage,
                    None,
                    format!(
                        "VdpSplit shard carries a {}-entry stage map (every layer must run on \
                         every chip)",
                        shard.chip_of_layer.len()
                    ),
                ));
            }
            let expect = shard.per_chip_xpes() * chips;
            if let Some(first) = shard.plan.layers.first() {
                if chips > 1 && first.total_xpes() != expect {
                    findings.push(Finding::new(
                        Code::ShardCoverage,
                        Some(0),
                        format!(
                            "VdpSplit grid spans {} XPE slots but {} chips x {} slots = {}",
                            first.total_xpes(),
                            chips,
                            shard.per_chip_xpes(),
                            expect
                        ),
                    ));
                }
            }
        }
        ShardPolicy::LayerPipeline => {
            if shard.chip_of_layer.len() != layers {
                findings.push(Finding::new(
                    Code::ShardCoverage,
                    None,
                    format!(
                        "stage map covers {} layers but the model has {}",
                        shard.chip_of_layer.len(),
                        layers
                    ),
                ));
            } else {
                let mut prev = 0usize;
                for (l, &chip) in shard.chip_of_layer.iter().enumerate() {
                    if chip >= chips {
                        findings.push(Finding::new(
                            Code::ShardCoverage,
                            Some(l),
                            format!(
                                "layer {} assigned to chip {} of a {}-chip group",
                                l, chip, chips
                            ),
                        ));
                        break;
                    }
                    if l == 0 && chip != 0 {
                        findings.push(Finding::new(
                            Code::ShardOverlap,
                            Some(0),
                            format!("stage map starts on chip {} (must start on chip 0)", chip),
                        ));
                        break;
                    }
                    if l > 0 && (chip < prev || chip > prev + 1) {
                        findings.push(Finding::new(
                            Code::ShardOverlap,
                            Some(l),
                            format!(
                                "stage map jumps from chip {} to chip {} at layer {} — stages \
                                 must be contiguous, non-decreasing layer ranges",
                                prev, chip, l
                            ),
                        ));
                        break;
                    }
                    prev = chip;
                }
            }
        }
    }
    let link = &shard.link;
    if link.bits_per_s <= 0.0
        || !link.bits_per_s.is_finite()
        || link.bits_per_act == 0
        || link.latency_s < 0.0
        || !link.latency_s.is_finite()
    {
        findings.push(Finding::new(
            Code::LinkCapacity,
            None,
            format!(
                "degenerate inter-chip channel: {} bits/act at {} bits/s, {} s latency — \
                 cross-chip activations could never arrive",
                link.bits_per_act, link.bits_per_s, link.latency_s
            ),
        ));
        return; // the balance math below divides by this bandwidth
    }
    if chips > 1 {
        let stages = shard.stage_times_s();
        let bottleneck = stages.iter().copied().fold(0.0_f64, f64::max);
        let link_serial = shard.transfers_per_frame() as f64 * link.occupancy_s();
        if link_serial > bottleneck {
            findings.push(Finding::new(
                Code::ShardImbalance,
                None,
                format!(
                    "the shared inter-chip channel needs {:.3e} s per frame vs the {:.3e} s \
                     bottleneck stage — the link, not the chips, sets the streaming rate",
                    link_serial, bottleneck
                ),
            ));
        }
        if shard.policy() == ShardPolicy::LayerPipeline {
            let mean: f64 = stages.iter().sum::<f64>() / chips as f64;
            if mean > 0.0 && bottleneck > 2.0 * mean {
                findings.push(Finding::new(
                    Code::ShardImbalance,
                    None,
                    format!(
                        "bottleneck stage {:.3e} s vs mean stage {:.3e} s — over half the \
                         group idles in steady state",
                        bottleneck, mean
                    ),
                ));
            }
        }
    }
}

// ---------------------------------------------------------------------
// Cross-layer admission checks
// ---------------------------------------------------------------------

/// The unit dependency graph must be a DAG whose edges point backwards
/// in frame-major order — the topological argument that makes the
/// frame-major XPE preference deadlock-free. Kahn's algorithm over the
/// producer edges; any unprocessed unit means a cycle.
fn check_unit_dag(fp: &FramePlan<'_>, findings: &mut Vec<Finding>) {
    let units = fp.units();
    let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); units];
    let mut indegree = vec![0usize; units];
    for u in 0..units {
        if let Some(p) = fp.producer(u) {
            if p >= u {
                findings.push(Finding::new(
                    Code::AdmissionCycle,
                    Some(fp.unit_layer(u)),
                    format!(
                        "unit {} depends on unit {} ahead of it in frame-major order",
                        u, p
                    ),
                ));
                return;
            }
            consumers[p].push(u);
            indegree[u] += 1;
        }
    }
    let mut ready: Vec<usize> = (0..units).filter(|&u| indegree[u] == 0).collect();
    let mut processed = 0usize;
    while let Some(u) = ready.pop() {
        processed += 1;
        for &c in &consumers[u] {
            indegree[c] -= 1;
            if indegree[c] == 0 {
                ready.push(c);
            }
        }
    }
    if processed != units {
        findings.push(Finding::new(
            Code::AdmissionCycle,
            None,
            format!("{} of {} units are trapped in a dependency cycle", units - processed, units),
        ));
    }
}

/// How the linter's independent threshold derivation classified a
/// consumer layer.
enum Thresholds {
    /// FC or raster-less flattening: the whole-map wait is *expected*.
    WholeMapExpected,
    /// Conv-shaped consumer that cannot use its window structure —
    /// sound (whole-map wait) but pipelining is lost.
    Fallback(&'static str),
    /// Per output position: the unclamped producer-activation reach.
    PerPosition(Vec<usize>),
}

/// Re-derive the receptive-field-exact admission thresholds from the
/// raw geometry — deliberately NOT calling into
/// [`crate::plan::FramePlan::need_acts`], and deliberately without its
/// final `min(produced)` clamp, so unproducible thresholds stay visible.
fn derive_exact(consumer: &GemmLayer, producer: &GemmLayer, produced: usize) -> Thresholds {
    let Some(geom) = consumer.geom else {
        return if consumer.h == 1 {
            Thresholds::WholeMapExpected
        } else {
            Thresholds::Fallback("consumer carries no window geometry")
        };
    };
    let out_hw = geom.out_hw();
    let positions = out_hw * out_hw;
    if positions == 0 || consumer.vdp_count() % positions != 0 {
        return Thresholds::Fallback("VDP count does not raster the output map");
    }
    let prod_positions = match producer.geom {
        Some(g) => g.out_hw() * g.out_hw(),
        None => producer.h,
    };
    if prod_positions == 0 || produced % prod_positions != 0 {
        return Thresholds::Fallback("producer activations do not raster its map");
    }
    let per_pos_acts = produced / prod_positions;
    let Some(prod_hw) = int_sqrt(prod_positions) else {
        return Thresholds::Fallback("producer map is not square");
    };
    let expected_in = if producer.pool { prod_hw / 2 } else { prod_hw };
    if producer.pool && prod_hw % 2 != 0 {
        return Thresholds::Fallback("2x2 pool on an odd producer map");
    }
    if geom.in_hw != expected_in {
        return Thresholds::Fallback("consumer input map does not chain onto the producer");
    }
    let mut needs = Vec::with_capacity(positions);
    for pos in 0..positions {
        let (mut r, mut c) = geom.last_input_rc(pos / out_hw, pos % out_hw);
        if producer.pool {
            r = 2 * r + 1;
            c = 2 * c + 1;
        }
        needs.push((r * prod_hw + c + 1) * per_pos_acts);
    }
    Thresholds::PerPosition(needs)
}

fn check_admission(fp: &FramePlan<'_>, unit: usize, findings: &mut Vec<Finding>) {
    let Some(prev) = fp.producer(unit) else {
        return;
    };
    let layer_idx = fp.unit_layer(unit);
    let consumer = &fp.layer_plan(unit).layer;
    let producer = &fp.layer_plan(prev).layer;
    let produced = fp.layer_plan(prev).vdp_count();
    match fp.admission() {
        AdmissionMode::Exact => {
            match derive_exact(consumer, producer, produced) {
                Thresholds::WholeMapExpected => {}
                Thresholds::Fallback(reason) => {
                    findings.push(Finding::new(
                        Code::AdmissionFallback,
                        Some(layer_idx),
                        format!(
                            "'{}' waits for the whole producer map ({}): cross-layer \
                             pipelining lost",
                            consumer.name, reason
                        ),
                    ));
                    check_runtime_agreement(fp, unit, layer_idx, produced, findings);
                }
                Thresholds::PerPosition(needs) => {
                    // Channel-chain consistency: a regular conv's vector
                    // size must be kernel² × the producer's activations
                    // per position (its channel count). This is what
                    // catches an off-by-one kernel that happens to keep
                    // the output map aligned.
                    let geom = consumer.geom.expect("PerPosition implies geometry");
                    let out = geom.out_hw();
                    let per_pos = consumer.vdp_count() / (out * out);
                    let prod_positions = match producer.geom {
                        Some(g) => g.out_hw() * g.out_hw(),
                        None => producer.h,
                    };
                    let per_pos_acts = produced / prod_positions;
                    if per_pos == consumer.k
                        && per_pos_acts > 0
                        && consumer.s != geom.kernel * geom.kernel * per_pos_acts
                    {
                        findings.push(Finding::new(
                            Code::GeomGemmMismatch,
                            Some(layer_idx),
                            format!(
                                "'{}' vector size {} != kernel^2 ({}) x producer channels ({})",
                                consumer.name,
                                consumer.s,
                                geom.kernel * geom.kernel,
                                per_pos_acts
                            ),
                        ));
                    }
                    for (pos, &need) in needs.iter().enumerate() {
                        if need > produced {
                            findings.push(Finding::new(
                                Code::AdmissionUnsatisfiable,
                                Some(layer_idx),
                                format!(
                                    "'{}' position {} waits for {} producer activations \
                                     but '{}' only ever drains {}",
                                    consumer.name, pos, need, producer.name, produced
                                ),
                            ));
                            return;
                        }
                        let v = pos * per_pos;
                        let runtime = fp.need_acts(unit, v);
                        if runtime != need.min(produced) {
                            findings.push(Finding::new(
                                Code::AdmissionDrift,
                                Some(layer_idx),
                                format!(
                                    "'{}' VDP {}: runtime threshold {} != re-derived {}",
                                    consumer.name,
                                    v,
                                    runtime,
                                    need.min(produced)
                                ),
                            ));
                            return;
                        }
                    }
                }
            }
        }
        AdmissionMode::RasterHalo(halo) => {
            if consumer.h == 1 {
                check_runtime_agreement(fp, unit, layer_idx, produced, findings);
                return;
            }
            // Independent re-derivation of the PR-4 halo rule: fraction
            // of the consumer's own raster plus a fixed halo, clamped to
            // the whole map — monotone and always producible.
            for position in 0..consumer.h {
                let frac = (position + 1) as f64 / consumer.h as f64;
                let expect = (((frac + halo).min(1.0) * produced as f64).ceil() as usize)
                    .min(produced);
                let runtime = fp.need_acts(unit, position * consumer.k);
                if runtime != expect || runtime > produced {
                    findings.push(Finding::new(
                        Code::AdmissionDrift,
                        Some(layer_idx),
                        format!(
                            "'{}' position {}: runtime halo threshold {} != re-derived {}",
                            consumer.name, position, runtime, expect
                        ),
                    ));
                    return;
                }
            }
        }
    }
}

/// For whole-map waits, the runtime rule must agree: every sampled VDP
/// of the consumer waits for exactly `produced` activations.
fn check_runtime_agreement(
    fp: &FramePlan<'_>,
    unit: usize,
    layer_idx: usize,
    produced: usize,
    findings: &mut Vec<Finding>,
) {
    let vdps = fp.layer_plan(unit).vdp_count();
    for v in [0, vdps / 2, vdps.saturating_sub(1)] {
        let runtime = fp.need_acts(unit, v);
        if runtime != produced {
            findings.push(Finding::new(
                Code::AdmissionDrift,
                Some(layer_idx),
                format!(
                    "whole-map wait expected ({} activations) but runtime admits VDP {} at {}",
                    produced, v, runtime
                ),
            ));
            return;
        }
    }
}

fn int_sqrt(n: usize) -> Option<usize> {
    let r = (n as f64).sqrt().round() as usize;
    (r * r == n).then_some(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::accelerator::AcceleratorConfig;
    use crate::workloads::Workload;

    fn chained() -> Workload {
        Workload::new(
            "chained",
            vec![
                GemmLayer::conv("c1", 8, 2, 3, 4),
                GemmLayer::conv("c2", 8, 4, 3, 4).with_pool(),
                GemmLayer::conv("c3", 4, 4, 3, 2),
                GemmLayer::fc("fc", 32, 10),
            ],
        )
    }

    #[test]
    fn clean_plan_has_no_errors() {
        for policy in [MappingPolicy::PcaLocal, MappingPolicy::SlicedSpread] {
            let plan =
                ExecutionPlan::compile(&AcceleratorConfig::oxbnn_5(), &chained(), policy);
            let findings = verify(&plan);
            assert!(!has_errors(&findings), "unexpected errors: {:?}", findings);
        }
    }

    #[test]
    fn halo_mode_lints_clean_too() {
        let plan = ExecutionPlan::compile(
            &AcceleratorConfig::oxbnn_50(),
            &chained(),
            MappingPolicy::PcaLocal,
        );
        let findings = verify_with(&plan, AdmissionMode::RasterHalo(0.125));
        assert!(!has_errors(&findings), "unexpected errors: {:?}", findings);
    }

    #[test]
    fn view_mismatch_detected() {
        let mut plan = ExecutionPlan::compile(
            &AcceleratorConfig::oxbnn_5(),
            &chained(),
            MappingPolicy::PcaLocal,
        );
        plan.workload.layers[1].k += 1;
        let findings = verify(&plan);
        assert!(findings.iter().any(|f| f.code == Code::ViewMismatch), "{:?}", findings);
    }

    #[test]
    fn gate_refuses_on_error() {
        let mut plan = ExecutionPlan::compile(
            &AcceleratorConfig::oxbnn_5(),
            &chained(),
            MappingPolicy::PcaLocal,
        );
        assert!(gate("ok", &plan).is_ok());
        plan.layers[0].xpc_count += 1;
        let rej = gate("bad", &plan).unwrap_err();
        assert!(rej.findings.iter().any(|f| f.code == Code::XpeOversubscribed));
        assert!(rej.to_string().contains("PL105"), "{}", rej);
    }

    #[test]
    fn compiled_shard_plans_lint_clean() {
        for shard_policy in ShardPolicy::all() {
            for chips in [1, 2, 4] {
                let shard = ShardPlan::compile(
                    &AcceleratorConfig::oxbnn_5(),
                    &chained(),
                    MappingPolicy::PcaLocal,
                    chips,
                    shard_policy,
                );
                let findings = verify_shard(&shard);
                assert!(
                    !has_errors(&findings),
                    "{:?} x {} chips: {:?}",
                    shard_policy,
                    chips,
                    findings
                );
                assert!(gate_shard("ok", &shard).is_ok());
            }
        }
    }

    #[test]
    fn shard_stage_map_mutations_are_detected() {
        let compile = |chips| {
            ShardPlan::compile(
                &AcceleratorConfig::oxbnn_5(),
                &chained(),
                MappingPolicy::PcaLocal,
                chips,
                ShardPolicy::LayerPipeline,
            )
        };
        // A layer assigned outside the group: coverage broken.
        let mut shard = compile(2);
        shard.chip_of_layer[0] = 5;
        let rej = gate_shard("escaped", &shard).unwrap_err();
        assert!(rej.findings.iter().any(|f| f.code == Code::ShardCoverage), "{}", rej);
        // A stage map shorter than the model: coverage broken.
        let mut shard = compile(2);
        shard.chip_of_layer.pop();
        assert!(verify_shard(&shard).iter().any(|f| f.code == Code::ShardCoverage));
        // Interleaved stages: chip 0 claims a layer after chip 1 started.
        let mut shard = compile(2);
        shard.chip_of_layer = vec![0, 1, 0, 1];
        let rej = gate_shard("interleaved", &shard).unwrap_err();
        assert!(rej.findings.iter().any(|f| f.code == Code::ShardOverlap));
        assert!(rej.to_string().contains("PL402"), "{}", rej);
        // A VdpSplit shard must not carry a stage map at all.
        let mut shard = ShardPlan::compile(
            &AcceleratorConfig::oxbnn_5(),
            &chained(),
            MappingPolicy::PcaLocal,
            2,
            ShardPolicy::VdpSplit,
        );
        shard.chip_of_layer = vec![0];
        assert!(verify_shard(&shard).iter().any(|f| f.code == Code::ShardCoverage));
    }

    #[test]
    fn degenerate_link_is_refused() {
        let mut shard = ShardPlan::compile(
            &AcceleratorConfig::oxbnn_5(),
            &chained(),
            MappingPolicy::PcaLocal,
            2,
            ShardPolicy::VdpSplit,
        );
        shard.link.bits_per_s = 0.0;
        let rej = gate_shard("no-link", &shard).unwrap_err();
        assert!(rej.findings.iter().any(|f| f.code == Code::LinkCapacity));
        assert!(rej.to_string().contains("PL403"), "{}", rej);
    }
}
