//! Regenerates paper Table II and benchmarks the scalability solver
//! (Eqs. 3–5) itself, plus an ablation: how FPS scales with the data rate
//! when N and γ follow the Table II trade-off (the "which DR should I
//! build?" question the table answers).
//!
//! Run: `cargo bench --bench bench_table2_scalability`

use oxbnn::analysis::pca_capacity::{gamma_calibrated, PAPER_TABLE2};
use oxbnn::analysis::scalability::ScalabilitySolver;
use oxbnn::api::analytic_report;
use oxbnn::arch::accelerator::{AcceleratorConfig, BitcountMode};
use oxbnn::util::bench::{Bencher, Table};
use oxbnn::workloads::Workload;

fn main() {
    let solver = ScalabilitySolver::default();

    // Solver throughput.
    let bencher = Bencher::from_env();
    let stats = bencher.run("table2_solve_all_rows", || solver.table2());
    println!(
        "solver: 7-row Table II in median {} (n={})\n",
        oxbnn::util::bench::fmt_secs(stats.median),
        stats.iters
    );

    // The table, measured vs paper.
    let mut t = Table::new(&[
        "DR", "P_PD-opt", "paper", "N", "paper", "gamma", "alpha", "paper",
    ]);
    let mut n_exact = 0;
    for (row, &(_, p_paper, n_paper, _, a_paper)) in
        solver.table2().iter().zip(PAPER_TABLE2.iter())
    {
        if row.n == n_paper {
            n_exact += 1;
        }
        t.row(&[
            format!("{}", row.dr_gsps),
            format!("{:.2}", row.p_pd_opt_dbm),
            format!("{:.2}", p_paper),
            format!("{}", row.n),
            format!("{}", n_paper),
            format!("{}", row.gamma),
            format!("{}", row.alpha),
            format!("{}", a_paper),
        ]);
    }
    println!("Table II — measured vs paper (N exact on {} of 7 rows)\n", n_exact);
    t.print();
    assert!(n_exact >= 6, "Table II N reproduction regressed: {}/7", n_exact);

    // Ablation: DR sweep at iso-area (XPE count scaled inversely with N
    // so total OXGs stay ~constant, like the paper's area normalization).
    println!("\nAblation — OXBNN FPS vs data rate at iso-area (vgg_small):\n");
    let total_gates = 53 * 100; // OXBNN_5's gate budget
    let wl = &Workload::evaluation_set()[0];
    let mut ab = Table::new(&["DR (GS/s)", "N", "XPEs", "alpha", "FPS", "FPS/W"]);
    for row in solver.table2() {
        let xpes = (total_gates / row.n).max(1);
        let cfg = AcceleratorConfig {
            name: format!("OXBNN_{}", row.dr_gsps),
            dr_gsps: row.dr_gsps,
            n: row.n,
            xpe_total: xpes,
            bitcount: BitcountMode::Pca { gamma: gamma_calibrated(row.dr_gsps) },
            ..AcceleratorConfig::oxbnn_5()
        };
        let perf = analytic_report(&cfg, wl);
        ab.row(&[
            format!("{}", row.dr_gsps),
            format!("{}", row.n),
            format!("{}", xpes),
            format!("{}", row.alpha),
            format!("{:.0}", perf.fps),
            format!("{:.1}", perf.fps_per_w),
        ]);
    }
    ab.print();
    println!("\nhigher DR buys FPS at iso-area (fewer, faster gates) — the paper's");
    println!("motivation for characterizing the whole DR range in Table II.");
}
