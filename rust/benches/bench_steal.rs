//! Bounded work-stealing (ISSUE-10) on vs off: the same whole-frame
//! pipelined event space, same workload, with the thief scheduler as the
//! only variable. Reports batched FPS, the busy/parked/idle three-way XPE
//! breakdown and the steal counters, and gates that stealing is real
//! (steals happen, parked time strictly drops) AND conservative
//! (identical transaction multisets, makespan never grows, zero
//! past-time clamps). Emits `BENCH_steal.json` (path overridable via
//! `OXBNN_BENCH_OUT`) so CI can track the numbers over time.
//!
//! Run: `cargo bench --bench bench_steal`
//! CI:  `OXBNN_BENCH_FAST=1 cargo bench --bench bench_steal`

use oxbnn::api::{BackendKind, Report, Session};
use oxbnn::arch::accelerator::AcceleratorConfig;
use oxbnn::arch::workload_sim::simulate_frames_pipelined_opts;
use oxbnn::mapping::layer::{ConvGeom, GemmLayer};
use oxbnn::plan::{AdmissionMode, ExecutionPlan};
use oxbnn::util::bench::{fmt_secs, Bencher, Table};
use oxbnn::util::json::Json;
use oxbnn::workloads::Workload;

fn main() {
    let fast = std::env::var("OXBNN_BENCH_FAST").is_ok();
    let frames: usize = if fast { 4 } else { 8 };

    // The dependency-stall-heavy shape from the pipeline bench: a conv
    // spine feeding a tiny FC tail on a small grid. XPEs holding FC work
    // park on the whole-map admission threshold while the spine drains —
    // exactly the stall the thief scheduler hides by running the next
    // frame's already-staged first-layer VDPs (prefetched when this
    // frame's layer 0 started, admitted trivially, never last-layer).
    let mut cfg = AcceleratorConfig::oxbnn_5();
    cfg.n = 9;
    cfg.xpe_total = 18;
    let w: usize = if fast { 12 } else { 16 };
    let (k3, k4) = if fast { (8, 8) } else { (16, 16) };
    let wl = Workload::new(
        "vgg_crop_steal",
        vec![
            GemmLayer::new("conv2", w * w, 1152, 8).with_geom(ConvGeom::new(3, 1, 1, w)),
            GemmLayer::new("conv3", w * w, 1152, k3).with_geom(ConvGeom::new(3, 1, 1, w)),
            GemmLayer::new("conv4", w * w, 2304, k4).with_geom(ConvGeom::new(3, 1, 1, w)),
            GemmLayer::fc("fc", 2048, 10),
        ],
    );
    println!(
        "steal bench — {} frames of {} ({}×{} maps) on {} ({} XPEs)\n",
        frames, wl.name, w, w, cfg.name, cfg.xpe_total
    );

    let session = |steal: bool| -> Report {
        Session::builder()
            .accelerator(cfg.clone())
            .workload(wl.clone())
            .backend(BackendKind::Event)
            .batch(frames)
            .pipeline(true)
            .steal(steal)
            .build()
            .expect("steal bench session")
            .run()
    };

    let bencher = Bencher::from_env();
    let off_stats = bencher.run("steal_off", || session(false));
    let on_stats = bencher.run("steal_on", || session(true));
    let off = session(false);
    let on = session(true);

    // The raw traces carry the three-way idle breakdown and counters.
    let plan = ExecutionPlan::compile(&cfg, &wl, oxbnn::api::default_policy(&cfg));
    let on_trace =
        simulate_frames_pipelined_opts(&plan, frames, AdmissionMode::Exact, true);
    let off_trace =
        simulate_frames_pipelined_opts(&plan, frames, AdmissionMode::Exact, false);

    let steals = on_trace.stats.counter("steal_dispatches");
    let stolen = on_trace.stats.counter("stolen_passes");
    let frac = |t: &oxbnn::arch::workload_sim::PipelineTrace| {
        (t.xpe_busy_fraction(), t.xpe_parked_fraction(), t.xpe_idle_fraction())
    };
    let (on_busy, on_parked, on_idle) = frac(&on_trace);
    let (off_busy, off_parked, off_idle) = frac(&off_trace);

    let mut t = Table::new(&["metric", "steal off", "steal on"]);
    t.row(&[
        "batched FPS".into(),
        format!("{:.1}", off.batched_fps()),
        format!("{:.1}", on.batched_fps()),
    ]);
    t.row(&[
        "batch latency".into(),
        fmt_secs(off.batch_latency_s),
        fmt_secs(on.batch_latency_s),
    ]);
    t.row(&[
        "XPE busy fraction".into(),
        format!("{:.3}", off_busy),
        format!("{:.3}", on_busy),
    ]);
    t.row(&[
        "XPE parked fraction".into(),
        format!("{:.3}", off_parked),
        format!("{:.3}", on_parked),
    ]);
    t.row(&[
        "XPE idle fraction".into(),
        format!("{:.3}", off_idle),
        format!("{:.3}", on_idle),
    ]);
    t.row(&[
        "steal dispatches".into(),
        format!("{}", off_trace.stats.counter("steal_dispatches")),
        format!("{}", steals),
    ]);
    t.row(&[
        "stolen passes".into(),
        format!("{}", off_trace.stats.counter("stolen_passes")),
        format!("{}", stolen),
    ]);
    t.row(&[
        "sim wall-clock".into(),
        fmt_secs(off_stats.median),
        fmt_secs(on_stats.median),
    ]);
    t.print();
    println!(
        "\n{} steals ({} passes) hid {:.1} → {:.1}% parked time; FPS {:.1} → {:.1}",
        steals,
        stolen,
        100.0 * off_parked,
        100.0 * on_parked,
        off.batched_fps(),
        on.batched_fps(),
    );

    // Acceptance gates (ISSUE 10): the thief scheduler must actually
    // steal on this stall-heavy shape, strictly convert parked time into
    // busy time, and stay a pure permutation — same multiset, makespan
    // never grows, no past-time clamps, and the strict frontier reports
    // zero steal activity.
    assert!(steals > 0, "stall-heavy geometry must trigger steals");
    assert!(stolen >= steals, "every steal dispatch runs at least one pass");
    assert_eq!(
        off_trace.stats.counter("steal_dispatches"),
        0,
        "strict frontier must never steal"
    );
    assert_eq!(
        off_trace.stats.counter("stolen_passes"),
        0,
        "strict frontier must never steal passes"
    );
    for key in ["passes", "pca_readouts", "activations", "psums"] {
        assert_eq!(
            on_trace.stats.counter(key),
            off_trace.stats.counter(key),
            "stealing must conserve the {} multiset",
            key
        );
    }
    assert_eq!(on_trace.stats.counter("clamped_events"), 0, "no past-time clamps (on)");
    assert_eq!(off_trace.stats.counter("clamped_events"), 0, "no past-time clamps (off)");
    assert!(
        on_trace.batch_latency_s <= off_trace.batch_latency_s * (1.0 + 1e-9),
        "stealing must never grow the makespan ({} vs {})",
        on_trace.batch_latency_s,
        off_trace.batch_latency_s
    );
    assert!(
        on.batched_fps() >= off.batched_fps() * (1.0 - 1e-9),
        "steal-on batched FPS {} must not lose to steal-off {}",
        on.batched_fps(),
        off.batched_fps()
    );
    assert!(
        on_parked < off_parked,
        "stealing must strictly reduce parked time ({:.4} vs {:.4})",
        on_parked,
        off_parked
    );
    for trace in [&on_trace, &off_trace] {
        let sum = trace.xpe_busy_fraction()
            + trace.xpe_parked_fraction()
            + trace.xpe_idle_fraction();
        assert!(
            (sum - 1.0).abs() < 1e-9,
            "busy/parked/idle must partition the makespan, got {}",
            sum
        );
    }
    println!("\nshape check OK: steals hide stalls without changing the transaction multiset");

    let json = Json::obj(vec![
        ("workload", Json::Str(wl.name.clone())),
        ("accelerator", Json::Str(cfg.name.clone())),
        ("frames", Json::Num(frames as f64)),
        ("steal_off_batched_fps", Json::Num(off.batched_fps())),
        ("steal_on_batched_fps", Json::Num(on.batched_fps())),
        ("speedup", Json::Num(on.batched_fps() / off.batched_fps())),
        ("steal_off_batch_latency_s", Json::Num(off_trace.batch_latency_s)),
        ("steal_on_batch_latency_s", Json::Num(on_trace.batch_latency_s)),
        ("steal_dispatches", Json::Num(steals as f64)),
        ("stolen_passes", Json::Num(stolen as f64)),
        ("steal_off_busy_fraction", Json::Num(off_busy)),
        ("steal_on_busy_fraction", Json::Num(on_busy)),
        ("steal_off_parked_fraction", Json::Num(off_parked)),
        ("steal_on_parked_fraction", Json::Num(on_parked)),
        ("steal_off_idle_fraction", Json::Num(off_idle)),
        ("steal_on_idle_fraction", Json::Num(on_idle)),
        ("parked_fraction_delta", Json::Num(off_parked - on_parked)),
        (
            "wake_dispatches",
            Json::Num(on_trace.stats.counter("wake_dispatches") as f64),
        ),
        (
            "fetch_wake_dispatches",
            Json::Num(on_trace.stats.counter("fetch_wake_dispatches") as f64),
        ),
        ("clamped_events", Json::Num(on_trace.stats.counter("clamped_events") as f64)),
        ("steal_off_sim_wall_s", Json::Num(off_stats.median)),
        ("steal_on_sim_wall_s", Json::Num(on_stats.median)),
    ]);
    let out = std::env::var("OXBNN_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_steal.json".to_string());
    std::fs::write(&out, json.to_string_pretty()).expect("write bench json");
    println!("wrote {}", out);
}
