//! Regenerates the paper Fig. 5 comparison with the event-driven
//! simulator: OXBNN's PCA mapping (all slices of a VDP on one XPE, analog
//! psum accumulation) vs the prior-work mapping (slices spread, psums
//! through ADC + reduction network), across vector sizes S — plus the
//! PCA-capacity (α) ablation from DESIGN.md.
//!
//! Run: `cargo bench --bench bench_fig5_mapping`

use oxbnn::arch::accelerator::{AcceleratorConfig, BitcountMode};
use oxbnn::arch::event_sim::simulate_layer;
use oxbnn::energy::power::EnergyModel;
use oxbnn::mapping::layer::GemmLayer;
use oxbnn::mapping::scheduler::MappingPolicy;
use oxbnn::util::bench::{Bencher, Table};

fn cfg(pca: bool, n: usize, xpes: usize, gamma: u64) -> AcceleratorConfig {
    let mut c = AcceleratorConfig::oxbnn_5();
    c.n = n;
    c.xpe_total = xpes;
    if pca {
        c.bitcount = BitcountMode::Pca { gamma };
    } else {
        c.bitcount = BitcountMode::Reduction { latency_s: 3.125e-9, psum_bits: 16 };
        c.energy = EnergyModel::robin();
    }
    c
}

fn main() {
    // Fig. 5 setting scaled up: N = 9, M = 9 XPEs per XPC, 2 XPCs.
    let n = 9;
    let xpes = 18;

    println!("Fig. 5 — PCA mapping vs psum-reduction mapping (event-driven TLM)\n");
    let mut t = Table::new(&[
        "S",
        "slices/VDP",
        "PCA latency",
        "reduction latency",
        "speedup",
        "PCA J",
        "reduction J",
    ]);
    for s in [9usize, 15, 45, 90, 180, 360, 720] {
        let layer = GemmLayer::new(format!("S{}", s), 16, s, 4);
        let pca = simulate_layer(&cfg(true, n, xpes, 29761), &layer, MappingPolicy::PcaLocal);
        let red = simulate_layer(
            &cfg(false, n, xpes, 0),
            &layer,
            MappingPolicy::SlicedSpread,
        );
        t.row(&[
            format!("{}", s),
            format!("{}", layer.slices(n)),
            oxbnn::util::bench::fmt_secs(pca.end_time_s),
            oxbnn::util::bench::fmt_secs(red.end_time_s),
            format!("{:.2}x", red.end_time_s / pca.end_time_s),
            format!("{:.2e}", pca.total_energy_j()),
            format!("{:.2e}", red.total_energy_j()),
        ]);
    }
    t.print();
    println!("\nS = 9 (= N): identical mappings, no reduction advantage (Fig. 5(c));");
    println!("S > N: the PCA absorbs psums in the analog domain and pulls ahead (Fig. 5(b) vs (a)).");

    // Ablation: PCA capacity α. Tiny γ forces mid-VDP saturation +
    // discharge stalls — quantifying why a large α matters (paper §IV-C).
    println!("\nAblation — PCA capacity γ vs latency (S = 180, N = 9, 20 slices/VDP):\n");
    let layer = GemmLayer::new("abl", 16, 180, 4);
    let mut ab = Table::new(&["gamma", "alpha(slices)", "latency", "saturations"]);
    for gamma in [9u64, 18, 45, 90, 29761] {
        let stats =
            simulate_layer(&cfg(true, n, xpes, gamma), &layer, MappingPolicy::PcaLocal);
        ab.row(&[
            format!("{}", gamma),
            format!("{}", gamma / n as u64),
            oxbnn::util::bench::fmt_secs(stats.end_time_s),
            format!("{}", stats.counter("pca_saturations")),
        ]);
    }
    ab.print();

    // Engine throughput (events/s) — the simulator is itself a deliverable.
    let bencher = Bencher::from_env();
    let layer = GemmLayer::new("bench", 32, 180, 8);
    let c = cfg(true, n, xpes, 29761);
    let stats = bencher.run("event_sim_layer", || {
        simulate_layer(&c, &layer, MappingPolicy::PcaLocal)
    });
    let events = simulate_layer(&c, &layer, MappingPolicy::PcaLocal).events_processed;
    println!(
        "\nevent engine: {} events in median {} → {:.2} M events/s",
        events,
        oxbnn::util::bench::fmt_secs(stats.median),
        events as f64 / stats.median / 1e6
    );
}
