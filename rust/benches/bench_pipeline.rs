//! Whole-frame pipelined event space vs the sequential `with_batch`
//! multiply, and receptive-field-EXACT admission vs the legacy 12.5%
//! raster halo (the ISSUE-5 differential): batched FPS, XPE idle
//! fraction, wake-index dispatch counts, and the conservation gates that
//! make the speedups honest — identical PASS/readout counts and zero
//! past-time clamps. Emits `BENCH_pipeline.json` (path overridable via
//! `OXBNN_BENCH_OUT`) so CI can track the numbers over time.
//!
//! Run: `cargo bench --bench bench_pipeline`
//! CI:  `OXBNN_BENCH_FAST=1 cargo bench --bench bench_pipeline`

use oxbnn::api::{BackendKind, Report, Session};
use oxbnn::arch::accelerator::AcceleratorConfig;
use oxbnn::arch::workload_sim::simulate_frames_pipelined_opts;
use oxbnn::mapping::layer::{ConvGeom, GemmLayer};
use oxbnn::plan::{AdmissionMode, ExecutionPlan};
use oxbnn::util::bench::{fmt_secs, Bencher, Table};
use oxbnn::util::json::Json;
use oxbnn::workloads::Workload;

fn main() {
    let fast = std::env::var("OXBNN_BENCH_FAST").is_ok();
    let frames: usize = if fast { 4 } else { 8 };

    // Scaled-down OXBNN (N = 9, 18 XPEs) on a VGG-style conv stack — the
    // Fig. 7 conv-workload stand-in: same-map 3×3 stride-1 windows (the
    // geometry class every Fig. 7 BNN's conv spine is built from) with
    // chain-consistent `ConvGeom`, feeding a deliberately unbalanced FC
    // tail that strands most XPEs idle — exactly the gap multi-frame
    // pipelining exists to fill.
    let mut cfg = AcceleratorConfig::oxbnn_5();
    cfg.n = 9;
    cfg.xpe_total = 18;
    let w: usize = if fast { 12 } else { 16 };
    let (k3, k4) = if fast { (8, 8) } else { (16, 16) };
    let wl = Workload::new(
        "vgg_crop_pipeline",
        vec![
            GemmLayer::new("conv2", w * w, 1152, 8).with_geom(ConvGeom::new(3, 1, 1, w)),
            GemmLayer::new("conv3", w * w, 1152, k3).with_geom(ConvGeom::new(3, 1, 1, w)),
            GemmLayer::new("conv4", w * w, 2304, k4).with_geom(ConvGeom::new(3, 1, 1, w)),
            GemmLayer::fc("fc", 2048, 10),
        ],
    );
    println!(
        "pipeline bench — {} frames of {} ({}×{} maps) on {} ({} XPEs)\n",
        frames, wl.name, w, w, cfg.name, cfg.xpe_total
    );

    let session = |pipelined: bool| -> Report {
        Session::builder()
            .accelerator(cfg.clone())
            .workload(wl.clone())
            .backend(BackendKind::Event)
            .batch(frames)
            .pipeline(pipelined)
            .build()
            .expect("pipeline bench session")
            .run()
    };

    let bencher = Bencher::from_env();
    let seq_stats = bencher.run("sequential_batch", || session(false));
    let pipe_stats = bencher.run("pipelined_batch", || session(true));
    let seq = session(false);
    let pipe = session(true);

    // The raw pipelined traces carry the idle-fraction / wake-index /
    // admission-mode metrics the report doesn't.
    // The admission differential runs on the STRICT frontier (steal off):
    // the exact-≥-halo ordering is the monotone-release argument of the
    // ISSUE-5 scheduler, which bounded stealing (its own bench,
    // `bench_steal`) deliberately perturbs.
    let plan = ExecutionPlan::compile(&cfg, &wl, oxbnn::api::default_policy(&cfg));
    let trace =
        simulate_frames_pipelined_opts(&plan, frames, AdmissionMode::Exact, false);
    let halo_trace = simulate_frames_pipelined_opts(
        &plan,
        frames,
        AdmissionMode::RasterHalo(0.125),
        false,
    );
    let tau = cfg.tau_s();
    let total_xpes = plan.layers[0].total_xpes();
    // Sequential idle fraction from first principles: the same photonic
    // work spread over the serial `frames × frame` makespan.
    let busy_total = seq.passes as f64 * frames as f64 * tau;
    let seq_idle = 1.0 - busy_total / (total_xpes as f64 * seq.batch_latency_s);
    let pipe_idle = trace.xpe_idle_fraction();
    let idle_delta = seq_idle - pipe_idle;
    let speedup = pipe.batched_fps() / seq.batched_fps();
    let exact_fps = trace.fps();
    let halo_fps = halo_trace.fps();

    let count = |r: &Report, key: &str| -> u64 {
        r.layers.iter().map(|l| l.counter(key)).sum()
    };
    let readouts_seq = count(&seq, "pca_readouts");
    let readouts_pipe = count(&pipe, "pca_readouts");

    let mut t = Table::new(&["metric", "sequential", "pipelined"]);
    t.row(&[
        "batched FPS".into(),
        format!("{:.1}", seq.batched_fps()),
        format!("{:.1}", pipe.batched_fps()),
    ]);
    t.row(&[
        "batch latency".into(),
        fmt_secs(seq.batch_latency_s),
        fmt_secs(pipe.batch_latency_s),
    ]);
    t.row(&[
        "first-frame latency".into(),
        fmt_secs(seq.frame_latency_s),
        fmt_secs(pipe.frame_latency_s),
    ]);
    t.row(&[
        "XPE idle fraction".into(),
        format!("{:.3}", seq_idle),
        format!("{:.3}", pipe_idle),
    ]);
    t.row(&[
        "passes / frame".into(),
        format!("{}", seq.passes),
        format!("{}", pipe.passes),
    ]);
    t.row(&[
        "PCA readouts / frame".into(),
        format!("{}", readouts_seq),
        format!("{}", readouts_pipe),
    ]);
    t.row(&[
        "sim wall-clock".into(),
        fmt_secs(seq_stats.median),
        fmt_secs(pipe_stats.median),
    ]);
    t.print();
    println!(
        "\npipelined batched FPS speedup: {:.2}x (idle {:.1}% → {:.1}%, Δ {:.1} pts)",
        speedup,
        100.0 * seq_idle,
        100.0 * pipe_idle,
        100.0 * idle_delta
    );
    println!(
        "admission: exact {:.1} FPS vs 12.5% halo {:.1} FPS ({:+.2}%); \
         {} wake dispatches over {} activations",
        exact_fps,
        halo_fps,
        100.0 * (exact_fps / halo_fps - 1.0),
        trace.stats.counter("wake_dispatches"),
        trace.stats.counter("activations"),
    );

    // Acceptance gates (ISSUE 4 + ISSUE 5): the pipelined speedup must be
    // real AND conservative — strictly higher batched FPS with the exact
    // same transaction multiset and no past-time clamps — and exact
    // receptive-field admission must not lose throughput to the halo
    // guess on this Fig. 7-style conv workload.
    assert!(
        pipe.batched_fps() > seq.batched_fps(),
        "pipelined batched FPS {} must strictly beat sequential {}",
        pipe.batched_fps(),
        seq.batched_fps()
    );
    assert_eq!(pipe.passes, seq.passes, "per-frame PASS count must be conserved");
    assert_eq!(readouts_pipe, readouts_seq, "per-frame readouts must be conserved");
    assert_eq!(
        trace.stats.counter("passes"),
        frames as u64 * seq.passes,
        "whole-batch PASS conservation"
    );
    assert_eq!(trace.stats.counter("clamped_events"), 0, "no past-time clamps");
    assert_eq!(
        halo_trace.stats.counter("clamped_events"),
        0,
        "no past-time clamps (halo differential)"
    );
    assert_eq!(
        halo_trace.stats.counter("passes"),
        trace.stats.counter("passes"),
        "admission mode must not change the transaction multiset"
    );
    assert!(
        exact_fps >= halo_fps * (1.0 - 1e-9),
        "exact admission {} FPS must not lose to the halo guess {} FPS",
        exact_fps,
        halo_fps
    );
    assert!(
        pipe_idle < seq_idle,
        "pipelining must reduce XPE idle time ({:.3} vs {:.3})",
        pipe_idle,
        seq_idle
    );
    println!("\nshape check OK: pipelined batch beats sequential with identical transactions");

    let json = Json::obj(vec![
        ("workload", Json::Str(wl.name.clone())),
        ("accelerator", Json::Str(cfg.name.clone())),
        ("frames", Json::Num(frames as f64)),
        ("sequential_batched_fps", Json::Num(seq.batched_fps())),
        ("pipelined_batched_fps", Json::Num(pipe.batched_fps())),
        ("speedup", Json::Num(speedup)),
        ("exact_admission_fps", Json::Num(exact_fps)),
        ("halo_admission_fps", Json::Num(halo_fps)),
        ("exact_over_halo", Json::Num(exact_fps / halo_fps)),
        ("sequential_batch_latency_s", Json::Num(seq.batch_latency_s)),
        ("pipelined_batch_latency_s", Json::Num(pipe.batch_latency_s)),
        ("sequential_frame_latency_s", Json::Num(seq.frame_latency_s)),
        ("pipelined_frame_latency_s", Json::Num(pipe.frame_latency_s)),
        ("sequential_xpe_idle_fraction", Json::Num(seq_idle)),
        ("pipelined_xpe_idle_fraction", Json::Num(pipe_idle)),
        ("idle_fraction_delta", Json::Num(idle_delta)),
        (
            "wake_dispatches",
            Json::Num(trace.stats.counter("wake_dispatches") as f64),
        ),
        ("passes_per_frame", Json::Num(seq.passes as f64)),
        (
            "peak_pending_events",
            Json::Num(trace.stats.counter("peak_pending_events") as f64),
        ),
        ("clamped_events", Json::Num(trace.stats.counter("clamped_events") as f64)),
        ("sequential_sim_wall_s", Json::Num(seq_stats.median)),
        ("pipelined_sim_wall_s", Json::Num(pipe_stats.median)),
    ]);
    let out = std::env::var("OXBNN_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_pipeline.json".to_string());
    std::fs::write(&out, json.to_string_pretty()).expect("write bench json");
    println!("wrote {}", out);
}
