//! Ablation benches for the design choices DESIGN.md calls out (beyond
//! the paper's own figures):
//!
//!   A1  memory-bandwidth sensitivity (is OXBNN_50 fabric- or IO-bound?)
//!   A2  reduction-network latency sweep (how slow can the baseline's
//!       psum path get before it dominates?)
//!   A3  XPE-count scaling at fixed N (parallelism utilization)
//!   A4  OXG process-variation Monte Carlo (single-MRR robustness +
//!       thermal trimming budget)
//!
//! Run: `cargo bench --bench bench_ablations`

use oxbnn::api::analytic_report;
use oxbnn::arch::accelerator::{AcceleratorConfig, BitcountMode};
use oxbnn::devices::variation::{max_tolerated_offset_nm, monte_carlo};
use oxbnn::util::bench::Table;
use oxbnn::workloads::Workload;

fn main() {
    let vgg = &Workload::evaluation_set()[0];

    // --- A1: memory bandwidth -------------------------------------------
    println!("A1 — eDRAM/H-tree bandwidth sensitivity (vgg_small FPS):\n");
    let mut t = Table::new(&["bandwidth", "OXBNN_5 FPS", "OXBNN_50 FPS", "LIGHTBULB FPS"]);
    for bw_tbps in [0.5, 1.0, 2.0, 8.0, 32.0, 1e6] {
        let fps = |mut cfg: AcceleratorConfig| {
            cfg.mem_bw_bits_per_s = bw_tbps * 1e12;
            analytic_report(&cfg, vgg).fps
        };
        t.row(&[
            if bw_tbps >= 1e5 { "infinite".into() } else { format!("{} Tb/s", bw_tbps) },
            format!("{:.0}", fps(AcceleratorConfig::oxbnn_5())),
            format!("{:.0}", fps(AcceleratorConfig::oxbnn_50())),
            format!("{:.0}", fps(oxbnn::baselines::lightbulb())),
        ]);
    }
    t.print();
    println!("OXBNN_50 saturates its fabric only once staging bandwidth is ample;\nOXBNN_5 is fabric-bound at every realistic bandwidth.\n");

    // --- A2: reduction latency -------------------------------------------
    println!("A2 — psum reduction latency sweep (ROBIN_PO on vgg_small):\n");
    let mut t = Table::new(&["t_red", "FPS", "slowdown vs OXBNN_5"]);
    let ox5 = analytic_report(&AcceleratorConfig::oxbnn_5(), vgg).fps;
    for t_red_ns in [0.0, 0.78, 1.5625, 3.125, 6.25, 12.5] {
        let mut cfg = oxbnn::baselines::robin_po();
        cfg.bitcount = BitcountMode::Reduction { latency_s: t_red_ns * 1e-9, psum_bits: 16 };
        let fps = analytic_report(&cfg, vgg).fps;
        t.row(&[
            format!("{} ns", t_red_ns),
            format!("{:.0}", fps),
            format!("{:.1}x", ox5 / fps),
        ]);
    }
    t.print();
    println!("Even a free reduction network leaves ROBIN behind (psum buffer\ntraffic + 2-MRR gates); Table III's 3.125 ns costs it the rest.\n");

    // --- A3: XPE scaling ---------------------------------------------------
    println!("A3 — XPE-count scaling, OXBNN N=19 @50 GS/s (resnet18 FPS):\n");
    let resnet = &Workload::evaluation_set()[1];
    let mut t = Table::new(&["XPEs", "FPS", "FPS/W", "parallel efficiency"]);
    let base_fps = {
        let mut cfg = AcceleratorConfig::oxbnn_50();
        cfg.xpe_total = 64;
        analytic_report(&cfg, resnet).fps
    };
    for xpes in [64usize, 128, 256, 512, 1123, 2246, 4492] {
        let mut cfg = AcceleratorConfig::oxbnn_50();
        cfg.xpe_total = xpes;
        let p = analytic_report(&cfg, resnet);
        let ideal = base_fps * xpes as f64 / 64.0;
        t.row(&[
            format!("{}", xpes),
            format!("{:.0}", p.fps),
            format!("{:.1}", p.fps_per_w),
            format!("{:.0}%", 100.0 * p.fps / ideal),
        ]);
    }
    t.print();
    println!("Scaling efficiency collapses once staging bandwidth, not the\nfabric, bounds each layer — matching the paper's choice to report\narea-normalized rather than max-area designs.\n");

    // --- A4: process variation --------------------------------------------
    println!("A4 — OXG under fabrication variation (1000-gate Monte Carlo):\n");
    let mut t = Table::new(&[
        "sigma (nm)",
        "failing gates (untrimmed)",
        "worst eye",
        "mean trim power (mW/gate)",
    ]);
    for sigma in [0.0, 0.05, 0.1, 0.2, 0.4] {
        let r = monte_carlo(sigma, 1000, 0xFAB);
        t.row(&[
            format!("{}", sigma),
            format!("{:.1}%", r.failing_fraction * 100.0),
            format!("{:.2}", r.worst_eye),
            format!("{:.2}", r.mean_trim_power_mw),
        ]);
    }
    t.print();
    println!(
        "\nuntrimmed tolerance: ±{:.2} nm (vs FWHM 0.35 nm); thermal trimming\nrecovers all gates at ~2 mW/gate — the robustness budget ROBIN's\nheterogeneous-MRR argument is about, quantified for the single-MRR OXG.",
        max_tolerated_offset_nm()
    );
}
