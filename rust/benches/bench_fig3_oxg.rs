//! Regenerates paper Fig. 3: OXG spectral/transient behaviour and the
//! device's data-rate limit, plus benchmarks the device-model throughput.
//!
//! Run: `cargo bench --bench bench_fig3_oxg`

use oxbnn::devices::oxg::{Oxg, OXG_MAX_DR_GSPS};
use oxbnn::util::bench::{Bencher, Table};
use oxbnn::util::rng::Rng;

fn main() {
    let gate = Oxg::new(1550.0);

    // Fig. 3(b): static levels.
    println!("Fig. 3(b) — through-port transmission per operand pair:\n");
    let mut t = Table::new(&["(i,w)", "T(λ_in)", "logic"]);
    for (i, w) in [(false, false), (false, true), (true, false), (true, true)] {
        t.row(&[
            format!("({},{})", i as u8, w as u8),
            format!("{:.3}", gate.transmission(i, w)),
            format!("{}", gate.xnor(i, w) as u8),
        ]);
    }
    t.print();
    println!("static eye: {:.3}\n", gate.static_eye());

    // Fig. 3(c) + DR sweep: error-free decode across rates.
    println!("Data-rate sweep (256-bit PRBS, device τ = 3 ps):\n");
    let mut sweep = Table::new(&["DR (GS/s)", "bit errors", "status"]);
    let mut rng = Rng::new(0xF16);
    let bits_i: Vec<bool> = (0..256).map(|_| rng.bool()).collect();
    let bits_w: Vec<bool> = (0..256).map(|_| rng.bool()).collect();
    let want: Vec<bool> = bits_i.iter().zip(&bits_w).map(|(a, b)| a == b).collect();
    for dr in [3.0, 5.0, 10.0, 20.0, 30.0, 40.0, 50.0, 64.0, 80.0, 100.0] {
        let trace = gate.transient(&bits_i, &bits_w, dr, 8, 3.0);
        let got = gate.decode_trace(&trace, 8);
        let errors = got.iter().zip(&want).filter(|(a, b)| a != b).count();
        sweep.row(&[
            format!("{}", dr),
            format!("{}", errors),
            if errors == 0 { "error-free".into() } else { "eye closed".to_string() },
        ]);
    }
    sweep.print();
    let max = gate.max_error_free_dr(3.0, 0xF16);
    println!(
        "\nmax error-free DR = {} GS/s (paper claims {} GS/s)",
        max, OXG_MAX_DR_GSPS
    );
    assert!(max >= OXG_MAX_DR_GSPS, "device model regressed below paper's 50 GS/s");

    // Device-model throughput (transient samples/s).
    let bencher = Bencher::from_env();
    let stats = bencher.run("oxg_transient_256b", || {
        gate.transient(&bits_i, &bits_w, 50.0, 8, 3.0)
    });
    let samples = 256 * 8;
    println!(
        "\ntransient model: {} samples in median {} → {:.1} M samples/s",
        samples,
        oxbnn::util::bench::fmt_secs(stats.median),
        samples as f64 / stats.median / 1e6
    );
}
