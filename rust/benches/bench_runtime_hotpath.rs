//! L3 hot-path benchmark: PJRT execute throughput on the AOT artifacts and
//! end-to-end serving throughput through the coordinator (router + batcher
//! + worker). This is the target of the EXPERIMENTS.md §Perf pass.
//!
//! Run: `cargo bench --bench bench_runtime_hotpath` (needs `make artifacts`)

use std::time::{Duration, Instant};

use oxbnn::coordinator::{InferenceRequest, Server, ServerConfig};
use oxbnn::runtime::{HostTensor, Manifest, Runtime};
use oxbnn::util::bench::{Bencher, Table};
use oxbnn::util::rng::Rng;

fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    }
    let manifest = Manifest::load(&dir).expect("manifest");
    let bencher = Bencher::from_env();
    let mut table = Table::new(&["path", "median", "throughput"]);

    // --- raw PJRT execute: GEMM kernel -----------------------------------
    let rt = Runtime::cpu().expect("PJRT");
    let art = manifest.get("xnor_gemm_bench").expect("artifact");
    let exe = rt.load_artifact(art).expect("compile");
    let (h, s) = (art.args[0].shape[0], art.args[0].shape[1]);
    let k = art.args[1].shape[1];
    let mut rng = Rng::new(9);
    let a = HostTensor::new(vec![h, s], rng.bits(h * s)).unwrap();
    let b = HostTensor::new(vec![s, k], rng.bits(s * k)).unwrap();
    let stats = bencher.run("pjrt_xnor_gemm", || exe.run(&[a.clone(), b.clone()]).unwrap());
    let bitops = (h * s * k) as f64;
    table.row(&[
        format!("PJRT xnor_gemm {}x{}x{}", h, s, k),
        oxbnn::util::bench::fmt_secs(stats.median),
        format!("{:.2} Gbitop/s", bitops / stats.median / 1e9),
    ]);

    // --- raw PJRT execute: tiny BNN forward -------------------------------
    let art = manifest.get("bnn_tiny").expect("artifact");
    let exe = rt.load_artifact(art).expect("compile");
    let weights: Vec<HostTensor> = oxbnn::coordinator::synthetic_weights(art, 1)
        .into_iter()
        .zip(&art.args[1..])
        .map(|(bits, spec)| HostTensor::new(spec.shape.clone(), bits).unwrap())
        .collect();
    let x = HostTensor::new(art.args[0].shape.clone(), rng.bits(art.args[0].element_count()))
        .unwrap();
    let stats = bencher.run("pjrt_bnn_tiny", || {
        let mut args = vec![x.clone()];
        args.extend(weights.iter().cloned());
        exe.run(&args).unwrap()
    });
    table.row(&[
        "PJRT bnn_tiny forward".into(),
        oxbnn::util::bench::fmt_secs(stats.median),
        format!("{:.1} frames/s", 1.0 / stats.median),
    ]);

    // --- serving path: coordinator end-to-end ----------------------------
    let mut cfg = ServerConfig::new(&dir, &["tiny"]);
    cfg.max_batch = 16;
    cfg.max_wait = Duration::from_micros(200);
    let server = Server::start(cfg).expect("server");
    let input_len = server.input_len("tiny").unwrap();
    // Closed-loop single client.
    let input: Vec<f32> = (0..input_len).map(|_| rng.f64() as f32).collect();
    let stats = bencher.run("serve_closed_loop", || {
        server
            .infer_blocking(InferenceRequest { model: "tiny".into(), input: input.clone() })
            .unwrap()
    });
    table.row(&[
        "serve closed-loop (1 client)".into(),
        oxbnn::util::bench::fmt_secs(stats.median),
        format!("{:.1} req/s", 1.0 / stats.median),
    ]);

    // Open-loop burst: submit N then collect (exercises batching).
    let n = 64;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|_| {
            server
                .submit(InferenceRequest { model: "tiny".into(), input: input.clone() })
                .unwrap()
                .1
        })
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let burst = t0.elapsed().as_secs_f64();
    table.row(&[
        format!("serve burst ({} queued)", n),
        oxbnn::util::bench::fmt_secs(burst),
        format!("{:.1} req/s", n as f64 / burst),
    ]);
    let m = server.metrics.lock().unwrap();
    let batch_line = format!(
        "batching during burst: mean batch size {:.2} over {} batches",
        m.mean_batch_size(),
        m.batches
    );
    drop(m);
    server.shutdown();

    // --- replica scale-out: same burst across 4 worker replicas ----------
    let mut cfg = ServerConfig::new(&dir, &["tiny"]);
    cfg.max_batch = 16;
    cfg.replicas = 4;
    let server = Server::start(cfg).expect("server");
    // Warm all replicas (absorb the one-time artifact compiles) before
    // timing the burst.
    let warm: Vec<_> = (0..8)
        .map(|_| {
            server
                .submit(InferenceRequest { model: "tiny".into(), input: input.clone() })
                .unwrap()
                .1
        })
        .collect();
    for rx in warm {
        rx.recv().unwrap().unwrap();
    }
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|_| {
            server
                .submit(InferenceRequest { model: "tiny".into(), input: input.clone() })
                .unwrap()
                .1
        })
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let burst4 = t0.elapsed().as_secs_f64();
    table.row(&[
        format!("serve burst ({} queued, 4 replicas)", n),
        oxbnn::util::bench::fmt_secs(burst4),
        format!("{:.1} req/s", n as f64 / burst4),
    ]);
    server.shutdown();

    println!("L3 hot path\n");
    table.print();
    println!("\n{}", batch_line);
}
