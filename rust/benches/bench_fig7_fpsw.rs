//! Regenerates paper Fig. 7(b): FPS/W (energy efficiency) of the five
//! accelerators across the four BNNs, with the paper's quoted gmean
//! ratios (6.8×/7.6×/2.14× for OXBNN_5; 4.9×/5.5×/1.5× for OXBNN_50) and
//! a power breakdown explaining where the energy goes.
//!
//! Run: `cargo bench --bench bench_fig7_fpsw`

use oxbnn::api::analytic_report;
use oxbnn::arch::accelerator::AcceleratorConfig;
use oxbnn::arch::perf::gmean;
use oxbnn::util::bench::Table;
use oxbnn::workloads::Workload;

fn main() {
    let accels = AcceleratorConfig::evaluation_set();
    let workloads = Workload::evaluation_set();

    let mut fpsw: Vec<Vec<f64>> = Vec::new();
    let mut table = Table::new(&[
        "accelerator",
        "vgg_small",
        "resnet18",
        "mobilenet_v2",
        "shufflenet_v2",
        "gmean",
    ]);
    for a in &accels {
        let row: Vec<f64> = workloads
            .iter()
            .map(|w| analytic_report(a, w).fps_per_w)
            .collect();
        table.row(&[
            a.name.clone(),
            format!("{:.1}", row[0]),
            format!("{:.1}", row[1]),
            format!("{:.1}", row[2]),
            format!("{:.1}", row[3]),
            format!("{:.1}", gmean(&row)),
        ]);
        fpsw.push(row);
    }
    println!("Fig. 7(b) — FPS/W\n");
    table.print();

    // Power/energy breakdown on VGG-small (context for the ratios).
    let mut pw = Table::new(&[
        "accelerator",
        "static W",
        "dyn J/frame",
        "avg W",
        "frame",
    ]);
    for a in &accels {
        let p = analytic_report(a, &workloads[0]);
        pw.row(&[
            a.name.clone(),
            format!("{:.2}", p.static_power_w),
            format!("{:.3e}", p.dynamic_energy_per_frame_j),
            format!("{:.2}", p.avg_power_w),
            oxbnn::util::bench::fmt_secs(p.frame_latency_s),
        ]);
    }
    println!("\nPower breakdown on vgg_small:\n");
    pw.print();

    let names = ["OXBNN_5", "OXBNN_50", "ROBIN_EO", "ROBIN_PO", "LIGHTBULB"];
    let idx = |n: &str| names.iter().position(|x| *x == n).unwrap();
    let ratio = |a: &str, b: &str| {
        let ra = &fpsw[idx(a)];
        let rb = &fpsw[idx(b)];
        gmean(&ra.iter().zip(rb).map(|(x, y)| x / y).collect::<Vec<_>>())
    };
    let mut cmp = Table::new(&["comparison", "measured gmean", "paper gmean"]);
    for (a, b, paper) in [
        ("OXBNN_5", "ROBIN_EO", "6.8x"),
        ("OXBNN_5", "ROBIN_PO", "7.6x"),
        ("OXBNN_5", "LIGHTBULB", "2.14x"),
        ("OXBNN_50", "ROBIN_EO", "4.9x"),
        ("OXBNN_50", "ROBIN_PO", "5.5x"),
        ("OXBNN_50", "LIGHTBULB", "1.5x"),
    ] {
        cmp.row(&[
            format!("{} / {}", a, b),
            format!("{:.1}x", ratio(a, b)),
            paper.to_string(),
        ]);
    }
    println!("\nGmean FPS/W ratios vs paper (shape target: OXBNN wins everywhere):\n");
    cmp.print();

    for base in ["ROBIN_EO", "ROBIN_PO", "LIGHTBULB"] {
        assert!(ratio("OXBNN_5", base) > 1.0, "OXBNN_5 must beat {}", base);
        assert!(ratio("OXBNN_50", base) > 1.0, "OXBNN_50 must beat {}", base);
    }
    println!("\nshape check OK: both OXBNN variants beat all baselines on FPS/W");
}
