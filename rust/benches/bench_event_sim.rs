//! Event-sim hot-path benchmark (the PR-3 perf trajectory): execution-plan
//! compile vs the legacy materialized `Schedule::plan`, streamed layer
//! simulation wall-clock and passes/sec on a VGG-scale conv layer, peak
//! per-XPE queue length, and the live-state memory ratio of streaming vs
//! materializing. Emits `BENCH_event_sim.json` (path overridable via
//! `OXBNN_BENCH_OUT`) so CI can track the numbers over time.
//!
//! Run: `cargo bench --bench bench_event_sim`
//! CI:  `OXBNN_BENCH_FAST=1 cargo bench --bench bench_event_sim`

use oxbnn::arch::accelerator::AcceleratorConfig;
use oxbnn::arch::event_sim::simulate_layer_planned;
use oxbnn::mapping::layer::GemmLayer;
use oxbnn::mapping::scheduler::{MappingPolicy, Schedule};
use oxbnn::plan::{ExecutionPlan, LayerPlan};
use oxbnn::util::bench::{fmt_secs, Bencher, Table};
use oxbnn::util::json::Json;
use oxbnn::workloads::Workload;

/// Peak resident set size (VmHWM) in bytes from /proc/self/status (None
/// off-Linux). Used to MEASURE the peak-memory gap rather than model it:
/// VmHWM is a monotone high-water mark, so a regression that transiently
/// re-materializes per-pass state on the hot path shows up here even if
/// it frees everything before returning (and even if the closed-form
/// byte formulas are left stale).
fn peak_rss_bytes() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: usize = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024)
}

fn main() {
    let bencher = Bencher::from_env();
    let cfg = AcceleratorConfig::oxbnn_5();
    // VGG-small conv2: 1024 output positions × 128 channels × 22 slices
    // at N = 53 — the layer whose materialized schedule used to cost
    // millions of heap structs (and a full clone of every queue).
    let layer = GemmLayer::new("vgg_conv2", 1024, 1152, 128);
    let policy = MappingPolicy::PcaLocal;
    let (n, m, xpcs) = (cfg.n, cfg.m(), cfg.xpc_count());

    println!("event-sim hot path — {} on {}\n", layer.name, cfg.name);

    let compile = bencher.run("plan_compile", || {
        LayerPlan::compile(&layer, policy, n, m, xpcs)
    });
    let plan = LayerPlan::compile(&layer, policy, n, m, xpcs);

    // Measured peak memory, streamed sim FIRST (small) so the
    // materialized baseline afterwards raises the high-water mark by its
    // own allocation, not the sim's.
    let hwm_base = peak_rss_bytes();
    let stats = simulate_layer_planned(&cfg, &plan);
    let hwm_after_sim = peak_rss_bytes();
    let sched = Schedule::plan(&layer, policy, n, m, xpcs);
    let sched_clone = sched.queues.clone(); // what LayerWorld used to hold
    let hwm_after_mat = peak_rss_bytes();
    let measured_sim_b = hwm_after_sim.zip(hwm_base).map(|(a, b)| a.saturating_sub(b));
    let measured_mat_b =
        hwm_after_mat.zip(hwm_after_sim).map(|(a, b)| a.saturating_sub(b));
    drop(sched_clone);
    drop(sched);

    let materialize = bencher.run("schedule_materialize_legacy", || {
        Schedule::plan(&layer, policy, n, m, xpcs)
    });
    let sim = bencher.run("streamed_layer_sim", || simulate_layer_planned(&cfg, &plan));

    // Whole-network plan compile, for the compile→cache→stream story.
    let wl = Workload::evaluation_set().remove(0); // vgg_small
    let frame_compile = bencher.run("frame_plan_compile_vgg_small", || {
        ExecutionPlan::compile(&cfg, &wl, policy)
    });

    let total_passes = plan.total_passes();
    let passes_per_sec = total_passes as f64 / sim.median;
    let peak_queue = plan.max_queue_len();
    // Modeled (closed-form) live state, for the trajectory record…
    let mem_streamed = plan.streamed_state_bytes();
    let mem_materialized = plan.materialized_bytes();
    let mem_ratio = mem_materialized as f64 / mem_streamed as f64;
    // …and the measured peak-RSS deltas, which are what the gate trusts.
    // A 64 KiB floor on the sim delta avoids a meaningless ratio when the
    // streamed sim fits entirely under the process's existing peak.
    let measured_ratio = measured_mat_b.zip(measured_sim_b).map(|(mat, sim_b)| {
        mat as f64 / (sim_b.max(64 * 1024)) as f64
    });

    let fmt_opt = |b: Option<usize>| {
        b.map(|v| format!("{} B", v)).unwrap_or_else(|| "n/a".to_string())
    };
    let mut t = Table::new(&["metric", "value"]);
    t.row(&["layer passes".into(), format!("{}", total_passes)]);
    t.row(&["events processed".into(), format!("{}", stats.events_processed)]);
    t.row(&["plan compile (streamed)".into(), fmt_secs(compile.median)]);
    t.row(&["schedule materialize (legacy)".into(), fmt_secs(materialize.median)]);
    t.row(&["frame plan compile (vgg_small)".into(), fmt_secs(frame_compile.median)]);
    t.row(&["layer sim wall-clock".into(), fmt_secs(sim.median)]);
    t.row(&["passes/sec".into(), format!("{:.3e}", passes_per_sec)]);
    t.row(&["peak per-XPE queue".into(), format!("{}", peak_queue)]);
    t.row(&["modeled state streamed".into(), format!("{} B", mem_streamed)]);
    t.row(&["modeled state materialized".into(), format!("{} B", mem_materialized)]);
    t.row(&["measured peak-RSS sim".into(), fmt_opt(measured_sim_b)]);
    t.row(&["measured peak-RSS materialized".into(), fmt_opt(measured_mat_b)]);
    t.row(&[
        "peak-memory ratio".into(),
        measured_ratio
            .map(|r| format!("{:.1}x (measured)", r))
            .unwrap_or_else(|| format!("{:.1}x (modeled)", mem_ratio)),
    ]);
    t.print();

    // Acceptance gates: the streamed sim's peak-memory growth must be
    // ≥10× below the materialized baseline (no per-pass allocation on
    // the hot path) — measured via VmHWM where available, modeled
    // otherwise — and the simulation must process every planned pass.
    match measured_ratio {
        Some(r) => assert!(
            r >= 10.0,
            "measured peak-RSS: streaming {:?} B vs materialized {:?} B — \
             want >= 10x, got {:.1}x (per-pass state crept back onto the hot path?)",
            measured_sim_b,
            measured_mat_b,
            r
        ),
        None => assert!(
            mem_ratio >= 10.0,
            "modeled live state: want >= 10x, got {:.1}x",
            mem_ratio
        ),
    }
    assert_eq!(stats.counter("passes"), total_passes as u64);
    assert!(
        compile.median <= materialize.median,
        "plan compile ({}) must not cost more than legacy materialization ({})",
        fmt_secs(compile.median),
        fmt_secs(materialize.median)
    );
    println!("\nshape check OK: streamed plan beats materialized baseline");

    let opt_num = |b: Option<usize>| Json::Num(b.map(|v| v as f64).unwrap_or(-1.0));
    let json = Json::obj(vec![
        ("layer", Json::Str(layer.name.clone())),
        ("accelerator", Json::Str(cfg.name.clone())),
        ("total_passes", Json::Num(total_passes as f64)),
        ("events_processed", Json::Num(stats.events_processed as f64)),
        ("plan_compile_s", Json::Num(compile.median)),
        ("schedule_materialize_s", Json::Num(materialize.median)),
        ("frame_plan_compile_s", Json::Num(frame_compile.median)),
        ("layer_sim_wall_s", Json::Num(sim.median)),
        ("passes_per_sec", Json::Num(passes_per_sec)),
        ("peak_queue_len", Json::Num(peak_queue as f64)),
        ("modeled_streamed_state_bytes", Json::Num(mem_streamed as f64)),
        ("modeled_materialized_bytes", Json::Num(mem_materialized as f64)),
        ("modeled_mem_ratio", Json::Num(mem_ratio)),
        ("measured_peak_rss_sim_bytes", opt_num(measured_sim_b)),
        ("measured_peak_rss_materialized_bytes", opt_num(measured_mat_b)),
        (
            "measured_peak_rss_ratio",
            Json::Num(measured_ratio.unwrap_or(-1.0)),
        ),
    ]);
    let out = std::env::var("OXBNN_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_event_sim.json".to_string());
    std::fs::write(&out, json.to_string_pretty()).expect("write bench json");
    println!("wrote {}", out);
}
