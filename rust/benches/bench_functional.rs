//! Functional-engine benchmark (the PR-8 perf trajectory): bit-packed
//! XNOR+popcount forward pass vs the scalar f32 reference on a VGG-scale
//! conv stack — ns/frame, frames/sec through the serving `BatchRunner` in
//! both modes (the serve-bench before/after numbers), heap allocations
//! per frame on the hot path, and the 64× weight-footprint compression.
//! Emits `BENCH_functional.json` (path overridable via `OXBNN_BENCH_OUT`)
//! so CI can track the numbers over time.
//!
//! Acceptance gate: the packed engine must clear ≥10× the f32 reference's
//! single-frame throughput (the ISSUE-8 floor; word-parallel XNOR over
//! 64-synapse lanes should land well above it).
//!
//! Run: `cargo bench --bench bench_functional`
//! CI:  `OXBNN_BENCH_FAST=1 cargo bench --bench bench_functional`

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use oxbnn::functional::{bnn, packed, FunctionalMode, PackedWeights};
use oxbnn::runtime::{ArgSpec, Artifact, BatchRunner, LayerDim, Runtime};
use oxbnn::util::bench::{fmt_secs, Bencher, Table};
use oxbnn::util::json::Json;
use oxbnn::util::rng::Rng;

/// Counting allocator: the "allocations per frame" metric measures the
/// hot path directly instead of trusting the buffer-reuse story.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Heap allocations per call of `f`, averaged over `iters` calls.
fn allocs_per_call<F: FnMut()>(iters: u64, mut f: F) -> f64 {
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..iters {
        f();
    }
    (ALLOCS.load(Ordering::Relaxed) - before) as f64 / iters as f64
}

/// A VGG-scale functional-engine artifact: 8×8×64 input through three
/// SAME-padded 3×3 convs (64 → 64 → pool → 128 channels) into a
/// 2048-deep FC — ~5.9M scalar VDP ops per frame, every conv row 576
/// synapses deep (9 packed words).
fn bench_artifact() -> Artifact {
    let layers = vec![
        LayerDim { kind: "conv".into(), h: 64, s: 576, k: 64, fmap_hw: 8 },
        LayerDim { kind: "conv".into(), h: 64, s: 576, k: 64, fmap_hw: 8 },
        LayerDim { kind: "conv".into(), h: 16, s: 576, k: 128, fmap_hw: 4 },
        LayerDim { kind: "fc".into(), h: 1, s: 2048, k: 10, fmap_hw: 1 },
    ];
    let mut args = vec![ArgSpec {
        name: "x".into(),
        shape: vec![1, 8, 8, 64],
        dtype: "f32".into(),
    }];
    for (i, l) in layers.iter().enumerate() {
        args.push(ArgSpec {
            name: format!("w{}", i),
            shape: vec![l.s, l.k],
            dtype: "f32".into(),
        });
    }
    Artifact {
        name: "bench_functional".into(),
        kind: "bnn_forward".into(),
        file: std::path::PathBuf::from("<synthetic>"),
        args,
        output_shape: vec![1, 10],
        layers,
        model: Some("bench".into()),
        input_hw: Some(8),
        input_channels: Some(64),
        num_classes: Some(10),
        apply_activation: None,
    }
}

fn main() {
    let bencher = Bencher::from_env();
    let artifact = bench_artifact();
    let mut rng = Rng::new(0xBE7C);
    let weights: Vec<Vec<f32>> =
        artifact.layers.iter().map(|l| rng.bits(l.s * l.k)).collect();
    let input_len = artifact.args[0].element_count();
    let frame: Vec<f32> = (0..input_len).map(|_| rng.f64() as f32 - 0.5).collect();
    let frame_ops: usize = artifact.layers.iter().map(|l| l.h * l.s * l.k).sum();

    println!(
        "functional engine — {} ({} scalar VDP ops/frame)\n",
        artifact.name, frame_ops
    );

    // Single-frame forward pass, scratch reused across calls in BOTH
    // engines (each engine's steady-state serving configuration).
    let packed_weights = PackedWeights::pack(&artifact, &weights);
    let refs = packed_weights.refs();
    let mut packed_scratch = packed::Scratch::default();
    let packed_stat = bencher.run("forward_packed", || {
        packed::forward_packed_with(&artifact, &frame, &refs, &mut packed_scratch)
    });
    let mut f32_scratch = bnn::Scratch::default();
    let f32_stat = bencher.run("forward_f32", || {
        bnn::forward_with(&artifact, &frame, &weights, &mut f32_scratch)
    });
    let speedup = f32_stat.median / packed_stat.median;

    // Sanity: both engines agree on the benchmarked frame.
    assert_eq!(
        packed::forward_packed_with(&artifact, &frame, &refs, &mut packed_scratch),
        bnn::forward_with(&artifact, &frame, &weights, &mut f32_scratch),
        "packed and f32 engines disagree on the bench frame"
    );

    // Allocations per frame AFTER warmup (the benches above warmed the
    // scratch buffers): the packed hot path must stay allocation-lean.
    let packed_allocs = allocs_per_call(16, || {
        std::hint::black_box(packed::forward_packed_with(
            &artifact,
            &frame,
            &refs,
            &mut packed_scratch,
        ));
    });
    let f32_allocs = allocs_per_call(16, || {
        std::hint::black_box(bnn::forward_with(
            &artifact,
            &frame,
            &weights,
            &mut f32_scratch,
        ));
    });

    // Serve-path frames/sec: the same artifact through `BatchRunner` (one
    // staged-weight upload, batched dispatch) in f32 mode (before) and
    // packed mode (after). Batch 8 crosses the batch-parallel threshold,
    // so the packed number includes the multi-core fan-out.
    let batch = 8usize;
    let frames: Vec<Vec<f32>> = (0..batch)
        .map(|_| (0..input_len).map(|_| rng.f64() as f32 - 0.5).collect())
        .collect();
    let frame_refs: Vec<&[f32]> = frames.iter().map(|f| f.as_slice()).collect();
    let fps_of = |mode: FunctionalMode| {
        let mut runner = BatchRunner::with_mode(
            Runtime::cpu().expect("sim runtime"),
            artifact.clone(),
            weights.clone(),
            mode,
        )
        .expect("runner");
        let stat = bencher.run(&format!("batch{}_{}", batch, mode), || {
            runner.run(&frame_refs).expect("batched run")
        });
        stat.throughput(batch as f64)
    };
    let fps_f32 = fps_of(FunctionalMode::F32);
    let fps_packed = fps_of(FunctionalMode::Packed);

    let f32_weight_bytes: usize = weights.iter().map(|w| w.len() * 4).sum();
    let packed_weight_bytes: usize =
        packed_weights.layers().iter().map(|m| m.packed_bytes()).sum();

    let mut t = Table::new(&["metric", "value"]);
    t.row(&["scalar VDP ops/frame".into(), format!("{}", frame_ops)]);
    t.row(&["f32 frame".into(), fmt_secs(f32_stat.median)]);
    t.row(&["packed frame".into(), fmt_secs(packed_stat.median)]);
    t.row(&["speedup".into(), format!("{:.1}x", speedup)]);
    t.row(&["f32 allocs/frame".into(), format!("{:.1}", f32_allocs)]);
    t.row(&["packed allocs/frame".into(), format!("{:.1}", packed_allocs)]);
    t.row(&["serve FPS (f32, before)".into(), format!("{:.1}", fps_f32)]);
    t.row(&["serve FPS (packed, after)".into(), format!("{:.1}", fps_packed)]);
    t.row(&["f32 weight bytes".into(), format!("{}", f32_weight_bytes)]);
    t.row(&["packed weight bytes".into(), format!("{}", packed_weight_bytes)]);
    t.print();

    // Acceptance gates. The throughput floor is the headline; the
    // allocation bound keeps the reuse contract honest (logits vector +
    // a couple of bookkeeping Vecs, nothing per-row or per-layer).
    assert!(
        speedup >= 10.0,
        "packed engine must be >= 10x the f32 reference, got {:.1}x \
         ({} vs {})",
        speedup,
        fmt_secs(packed_stat.median),
        fmt_secs(f32_stat.median)
    );
    assert!(
        packed_allocs <= 8.0,
        "packed hot path allocates {:.1} times/frame — per-frame buffer \
         reuse regressed",
        packed_allocs
    );
    println!("\ngate OK: packed {:.1}x faster than f32 reference", speedup);

    let json = Json::obj(vec![
        ("artifact", Json::Str(artifact.name.clone())),
        ("frame_ops", Json::Num(frame_ops as f64)),
        ("f32_ns_per_frame", Json::Num(f32_stat.median * 1e9)),
        ("packed_ns_per_frame", Json::Num(packed_stat.median * 1e9)),
        ("speedup", Json::Num(speedup)),
        ("f32_allocs_per_frame", Json::Num(f32_allocs)),
        ("packed_allocs_per_frame", Json::Num(packed_allocs)),
        ("serve_batch", Json::Num(batch as f64)),
        ("serve_fps_f32", Json::Num(fps_f32)),
        ("serve_fps_packed", Json::Num(fps_packed)),
        ("f32_weight_bytes", Json::Num(f32_weight_bytes as f64)),
        ("packed_weight_bytes", Json::Num(packed_weight_bytes as f64)),
    ]);
    let out = std::env::var("OXBNN_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_functional.json".to_string());
    std::fs::write(&out, json.to_string_pretty()).expect("write bench json");
    println!("wrote {}", out);
}
