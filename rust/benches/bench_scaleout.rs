//! Multi-chip scale-out bench (ISSUE 9): K = 1/2/4 batched FPS for a
//! VDP-split group on the paper's flagship pairing (vgg_small on
//! OXBNN_50), inter-chip link occupancy on the transaction-level event
//! path, and the serving rate of a K-chip group staged as ONE
//! high-throughput replica. The acceptance gates mirror the CLI
//! criterion: 4 chips strictly beat 1 on batched FPS with identical
//! per-layer work multisets. Emits `BENCH_scaleout.json` (path
//! overridable via `OXBNN_BENCH_OUT`) so CI can track the numbers.
//!
//! Run: `cargo bench --bench bench_scaleout`
//! CI:  `OXBNN_BENCH_FAST=1 cargo bench --bench bench_scaleout`

use std::time::Instant;

use oxbnn::api::{BackendKind, Session};
use oxbnn::arch::accelerator::AcceleratorConfig;
use oxbnn::arch::workload_sim::simulate_frames_sharded;
use oxbnn::coordinator::{InferenceRequest, ServerConfig};
use oxbnn::mapping::layer::{ConvGeom, GemmLayer};
use oxbnn::plan::{ShardPlan, ShardPolicy};
use oxbnn::serving::ModelRegistry;
use oxbnn::util::bench::{fmt_secs, Bencher, Table};
use oxbnn::util::json::Json;
use oxbnn::workloads::Workload;

fn main() {
    let fast = std::env::var("OXBNN_BENCH_FAST").is_ok();
    let batch: usize = if fast { 4 } else { 8 };
    let bencher = Bencher::from_env();

    // -----------------------------------------------------------------
    // 1. Analytic K-sweep: vgg_small on OXBNN_50, VDP-split group.
    // -----------------------------------------------------------------
    let cfg = AcceleratorConfig::oxbnn_50();
    let wl = Workload::evaluation_set()
        .into_iter()
        .find(|w| w.name == "vgg_small")
        .expect("vgg_small is in the evaluation set");
    println!(
        "scale-out bench — {} on {}, batch {}, VDP-split groups\n",
        wl.name, cfg.name, batch
    );
    let run = |chips: usize| {
        Session::builder()
            .accelerator(cfg.clone())
            .workload(wl.clone())
            .backend(BackendKind::Analytic)
            .batch(batch)
            .pipeline(true)
            .chips(chips)
            .shard_policy(ShardPolicy::VdpSplit)
            .build()
            .expect("scale-out bench session")
            .run()
    };
    let reports: Vec<_> = [1usize, 2, 4].iter().map(|&k| (k, run(k))).collect();
    let fps1 = reports[0].1.batched_fps();
    let mut t = Table::new(&["chips", "batched FPS", "speedup", "efficiency"]);
    for (k, r) in &reports {
        let fps = r.batched_fps();
        t.row(&[
            format!("{}", k),
            format!("{:.1}", fps),
            format!("{:.2}x", fps / fps1),
            format!("{:.2}", fps / (*k as f64 * fps1)),
        ]);
    }
    t.print();

    // -----------------------------------------------------------------
    // 2. Event path: link occupancy on a conv crop (4-chip VDP split).
    // -----------------------------------------------------------------
    let mut small = AcceleratorConfig::oxbnn_5();
    small.n = 9;
    small.xpe_total = 18;
    let w: usize = if fast { 12 } else { 16 };
    let crop = Workload::new(
        "vgg_crop_scaleout",
        vec![
            GemmLayer::new("conv2", w * w, 1152, 8).with_geom(ConvGeom::new(3, 1, 1, w)),
            GemmLayer::new("conv3", w * w, 1152, 8).with_geom(ConvGeom::new(3, 1, 1, w)),
            GemmLayer::fc("fc", 2048, 10),
        ],
    );
    let frames: usize = if fast { 4 } else { 8 };
    let policy = oxbnn::api::default_policy(&small);
    let shard1 = ShardPlan::compile(&small, &crop, policy, 1, ShardPolicy::VdpSplit);
    let shard4 = ShardPlan::compile(&small, &crop, policy, 4, ShardPolicy::VdpSplit);
    let one_stats = bencher.run("event_1chip", || simulate_frames_sharded(&shard1, frames));
    let four_stats = bencher.run("event_4chip", || simulate_frames_sharded(&shard4, frames));
    let t1 = simulate_frames_sharded(&shard1, frames);
    let t4 = simulate_frames_sharded(&shard4, frames);
    let occupancy = t4.link_occupancy_fraction();
    println!(
        "\nevent crop ({} frames): 1-chip {:.1} FPS vs 4-chip {:.1} FPS; link occupancy \
         {:.1}% over {} transfers ({} busy); sim wall {} vs {}",
        frames,
        t1.fps(),
        t4.fps(),
        100.0 * occupancy,
        t4.link_transfers,
        fmt_secs(t4.link_busy_s),
        fmt_secs(one_stats.median),
        fmt_secs(four_stats.median),
    );

    // -----------------------------------------------------------------
    // 3. Serving: a 2-chip group staged as ONE replica, measured rate.
    // -----------------------------------------------------------------
    let mut scfg = ServerConfig::synthetic(&[]);
    scfg.max_batch = 4;
    scfg.queue_depth = 64;
    let reg = ModelRegistry::synthetic(scfg);
    let entry = reg.load_with("m", 1, 2).expect("2-chip group loads");
    let requests: usize = if fast { 32 } else { 128 };
    let input = vec![0.25f32; entry.input_len];
    let wall = Instant::now();
    for _ in 0..requests {
        entry
            .server
            .infer_blocking(InferenceRequest { model: "m".into(), input: input.clone() })
            .expect("group replica serves");
    }
    let serve_fps = requests as f64 / wall.elapsed().as_secs_f64();
    println!(
        "group serving: {} requests through the 2-chip group replica at {:.0} req/s \
         (photonic reference {:.1} FPS)",
        requests, serve_fps, entry.photonic_fps
    );
    reg.drain_all();

    // Acceptance gates: scale-out must be real AND conservative.
    let (fps2, fps4) = (reports[1].1.batched_fps(), reports[2].1.batched_fps());
    assert!(
        fps4 > fps1,
        "4-chip batched FPS {} must strictly beat 1-chip {}",
        fps4,
        fps1
    );
    assert!(fps2 >= fps1 && fps4 >= fps2, "FPS must be monotone in chips");
    assert!(
        fps4 <= 4.0 * fps1 * (1.0 + 1e-9),
        "super-linear scaling: {} vs 4 x {}",
        fps4,
        fps1
    );
    for (k, r) in &reports[1..] {
        assert_eq!(r.passes, reports[0].1.passes, "K={}: PASS conservation", k);
        assert_eq!(r.psums, reports[0].1.psums, "K={}: psum conservation", k);
    }
    assert_eq!(
        t4.stats.counter("passes"),
        t1.stats.counter("passes"),
        "event-path PASS conservation across sharding"
    );
    assert_eq!(t4.stats.counter("clamped_events"), 0, "no past-time clamps");
    assert!(t4.link_transfers > 0, "a 4-chip VDP split must use the link");
    assert!(
        occupancy > 0.0 && occupancy <= 1.0,
        "link occupancy {} out of range",
        occupancy
    );
    assert!(serve_fps > 0.0 && serve_fps.is_finite());
    println!("\nshape check OK: 4-chip group beats 1 chip with identical transactions");

    let json = Json::obj(vec![
        ("workload", Json::Str(wl.name.clone())),
        ("accelerator", Json::Str(cfg.name.clone())),
        ("batch", Json::Num(batch as f64)),
        ("shard_policy", Json::Str("vdp".to_string())),
        ("fps_k1", Json::Num(fps1)),
        ("fps_k2", Json::Num(fps2)),
        ("fps_k4", Json::Num(fps4)),
        ("speedup_k4", Json::Num(fps4 / fps1)),
        ("efficiency_k4", Json::Num(fps4 / (4.0 * fps1))),
        ("event_crop_frames", Json::Num(frames as f64)),
        ("event_fps_k1", Json::Num(t1.fps())),
        ("event_fps_k4", Json::Num(t4.fps())),
        ("link_occupancy_k4", Json::Num(occupancy)),
        ("link_transfers_k4", Json::Num(t4.link_transfers as f64)),
        ("link_busy_s_k4", Json::Num(t4.link_busy_s)),
        ("group_chips", Json::Num(entry.chips as f64)),
        ("group_serve_fps", Json::Num(serve_fps)),
        ("group_photonic_fps", Json::Num(entry.photonic_fps)),
        ("event_sim_wall_k1_s", Json::Num(one_stats.median)),
        ("event_sim_wall_k4_s", Json::Num(four_stats.median)),
    ]);
    let out = std::env::var("OXBNN_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_scaleout.json".to_string());
    std::fs::write(&out, json.to_string_pretty()).expect("write bench json");
    println!("wrote {}", out);
}
