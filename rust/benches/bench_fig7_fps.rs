//! Regenerates paper Fig. 7(a): FPS of OXBNN_5 / OXBNN_50 vs ROBIN_EO /
//! ROBIN_PO / LIGHTBULB across the four BNNs, plus the gmean speedup rows
//! the paper quotes (62×/8×/7× and 54×/7×/16×).
//!
//! Run: `cargo bench --bench bench_fig7_fps`

use oxbnn::api::analytic_report;
use oxbnn::arch::accelerator::AcceleratorConfig;
use oxbnn::arch::perf::gmean;
use oxbnn::util::bench::{Bencher, Table};
use oxbnn::util::threadpool::parallel_map;
use oxbnn::workloads::Workload;

fn main() {
    let accels = AcceleratorConfig::evaluation_set();
    let workloads = Workload::evaluation_set();

    // Time the sweep itself (the simulator is a deliverable; its speed is
    // what lets us run ablations — see EXPERIMENTS.md §Perf).
    let bencher = Bencher::from_env();
    let stats = bencher.run("fig7_full_sweep", || {
        let jobs: Vec<(AcceleratorConfig, Workload)> = accels
            .iter()
            .flat_map(|a| workloads.iter().map(move |w| (a.clone(), w.clone())))
            .collect();
        parallel_map(jobs, 8, |(a, w)| analytic_report(&a, &w).fps)
    });
    println!(
        "sweep time (20 accelerator x workload sims): median {} (n={})\n",
        oxbnn::util::bench::fmt_secs(stats.median),
        stats.iters
    );

    // The figure itself.
    let mut fps: Vec<Vec<f64>> = Vec::new();
    let mut table = Table::new(&[
        "accelerator",
        "vgg_small",
        "resnet18",
        "mobilenet_v2",
        "shufflenet_v2",
        "gmean",
    ]);
    for a in &accels {
        let row: Vec<f64> = workloads.iter().map(|w| analytic_report(a, w).fps).collect();
        table.row(&[
            a.name.clone(),
            format!("{:.0}", row[0]),
            format!("{:.0}", row[1]),
            format!("{:.0}", row[2]),
            format!("{:.0}", row[3]),
            format!("{:.0}", gmean(&row)),
        ]);
        fps.push(row);
    }
    println!("Fig. 7(a) — FPS (log scale in the paper)\n");
    table.print();

    // Gmean speedups vs each baseline (paper's quoted ratios).
    let names = ["OXBNN_5", "OXBNN_50", "ROBIN_EO", "ROBIN_PO", "LIGHTBULB"];
    let idx = |n: &str| names.iter().position(|x| *x == n).unwrap();
    let ratio = |a: &str, b: &str| {
        let ra = &fps[idx(a)];
        let rb = &fps[idx(b)];
        gmean(&ra.iter().zip(rb).map(|(x, y)| x / y).collect::<Vec<_>>())
    };
    let mut cmp = Table::new(&["comparison", "measured gmean", "paper gmean"]);
    for (a, b, paper) in [
        ("OXBNN_50", "ROBIN_EO", "62x"),
        ("OXBNN_50", "ROBIN_PO", "8x"),
        ("OXBNN_50", "LIGHTBULB", "7x"),
        ("OXBNN_5", "ROBIN_EO", "54x"),
        ("OXBNN_5", "ROBIN_PO", "7x"),
        ("OXBNN_5", "LIGHTBULB", "16x"),
    ] {
        cmp.row(&[
            format!("{} / {}", a, b),
            format!("{:.1}x", ratio(a, b)),
            paper.to_string(),
        ]);
    }
    println!("\nGmean FPS speedups vs paper (shape target: OXBNN wins everywhere):\n");
    cmp.print();

    // Shape assertions (the bench fails loudly if the story breaks).
    for base in ["ROBIN_EO", "ROBIN_PO", "LIGHTBULB"] {
        assert!(ratio("OXBNN_50", base) > 1.0, "OXBNN_50 must beat {}", base);
        assert!(ratio("OXBNN_5", base) > 1.0, "OXBNN_5 must beat {}", base);
    }
    println!("\nshape check OK: both OXBNN variants beat all baselines on FPS");
}
