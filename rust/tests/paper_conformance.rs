//! Paper-conformance regression suite (tier-1).
//!
//! The Fig. 7 / Table II claims used to be asserted only inside `cargo
//! bench` targets, so `cargo test` could pass while a refactor silently
//! drifted the reproduction away from the paper. This suite pins them as
//! plain tests:
//!
//! * **Fig. 7(a)/(b) orderings** — both OXBNN variants beat ROBIN_EO,
//!   ROBIN_PO and LIGHTBULB on FPS and FPS/W on *every* evaluation BNN.
//! * **Fig. 7 gmean ratios** — pinned against the values this
//!   reproduction measures (recorded below next to the paper's quoted
//!   numbers), with ±25% drift tolerance. The reproduction preserves the
//!   paper's ordering story but not its exact magnitudes (the paper's
//!   per-device constants are not all published; DESIGN.md lists the
//!   calibration constants used here), so the pins are against *our*
//!   measured baseline: the suite catches regressions of this codebase,
//!   not disagreement with the paper's lab.
//! * **Table II shapes** — solver N matches the paper on ≥ 6 of 7 rows,
//!   N is non-increasing in DR, α = ⌊γ/N⌋.
//! * **Event-domain conformance** — the transaction-level simulator
//!   upholds the same claims under BOTH execution modes: sequential
//!   per-layer event spaces and the whole-frame pipelined event space.

use oxbnn::analysis::pca_capacity::PAPER_TABLE2;
use oxbnn::analysis::scalability::ScalabilitySolver;
use oxbnn::api::{analytic_report, BackendKind, Report, Session};
use oxbnn::arch::accelerator::{AcceleratorConfig, BitcountMode};
use oxbnn::arch::perf::gmean;
use oxbnn::mapping::layer::GemmLayer;
use oxbnn::workloads::Workload;

/// Accelerator names in `evaluation_set` order.
const NAMES: [&str; 5] = ["OXBNN_5", "OXBNN_50", "ROBIN_EO", "ROBIN_PO", "LIGHTBULB"];

/// Fig. 7 metric grid: per accelerator, the four per-workload values.
fn fig7_grid(metric: impl Fn(&Report) -> f64) -> Vec<(String, Vec<f64>)> {
    let workloads = Workload::evaluation_set();
    AcceleratorConfig::evaluation_set()
        .into_iter()
        .map(|a| {
            let row = workloads
                .iter()
                .map(|w| metric(&analytic_report(&a, w)))
                .collect();
            (a.name.clone(), row)
        })
        .collect()
}

fn row<'a>(grid: &'a [(String, Vec<f64>)], name: &str) -> &'a [f64] {
    &grid
        .iter()
        .find(|(n, _)| n.as_str() == name)
        .expect("known accelerator")
        .1
}

/// Gmean of the per-workload ratios a/b (the Fig. 7 "gmean speedup" rows).
fn gmean_ratio(grid: &[(String, Vec<f64>)], a: &str, b: &str) -> f64 {
    let ra = row(grid, a);
    let rb = row(grid, b);
    gmean(&ra.iter().zip(rb).map(|(x, y)| x / y).collect::<Vec<f64>>())
}

fn assert_within(measured: f64, pinned: f64, rel_tol: f64, what: &str) {
    let rel = (measured - pinned).abs() / pinned;
    assert!(
        rel <= rel_tol,
        "{}: measured {:.3} vs pinned {:.3} (drift {:.1}% > {:.0}%)",
        what,
        measured,
        pinned,
        rel * 100.0,
        rel_tol * 100.0
    );
}

#[test]
fn fig7_oxbnn_beats_every_baseline_on_every_workload() {
    for (metric_name, grid) in [
        ("FPS", fig7_grid(|r| r.fps)),
        ("FPS/W", fig7_grid(|r| r.fps_per_w)),
    ] {
        for ox in ["OXBNN_5", "OXBNN_50"] {
            for base in ["ROBIN_EO", "ROBIN_PO", "LIGHTBULB"] {
                for (i, (o, b)) in
                    row(&grid, ox).iter().zip(row(&grid, base)).enumerate()
                {
                    assert!(
                        o > b,
                        "{}: {} must beat {} on workload #{} ({} vs {})",
                        metric_name,
                        ox,
                        base,
                        i,
                        o,
                        b
                    );
                }
            }
        }
    }
}

#[test]
fn fig7_fps_gmean_speedups_pinned() {
    let grid = fig7_grid(|r| r.fps);
    // (a, b, this reproduction's measured gmean, paper's quoted gmean).
    // The pin is our measured baseline; the paper column documents the
    // target the ordering story comes from.
    for (a, b, pinned, _paper) in [
        ("OXBNN_50", "ROBIN_EO", 92.99, "62x"),
        ("OXBNN_50", "ROBIN_PO", 87.87, "8x"),
        ("OXBNN_50", "LIGHTBULB", 39.75, "7x"),
        ("OXBNN_5", "ROBIN_EO", 8.42, "54x"),
        ("OXBNN_5", "ROBIN_PO", 7.96, "7x"),
        ("OXBNN_5", "LIGHTBULB", 3.60, "16x"),
    ] {
        let measured = gmean_ratio(&grid, a, b);
        assert_within(measured, pinned, 0.25, &format!("FPS gmean {}/{}", a, b));
    }
}

#[test]
fn fig7_fpsw_gmean_ratios_pinned() {
    let grid = fig7_grid(|r| r.fps_per_w);
    for (a, b, pinned, _paper) in [
        ("OXBNN_5", "ROBIN_EO", 50.29, "6.8x"),
        ("OXBNN_5", "ROBIN_PO", 15.34, "7.6x"),
        ("OXBNN_5", "LIGHTBULB", 25.97, "2.14x"),
        ("OXBNN_50", "ROBIN_EO", 56.65, "4.9x"),
        ("OXBNN_50", "ROBIN_PO", 17.28, "5.5x"),
        ("OXBNN_50", "LIGHTBULB", 29.26, "1.5x"),
    ] {
        let measured = gmean_ratio(&grid, a, b);
        assert_within(measured, pinned, 0.25, &format!("FPS/W gmean {}/{}", a, b));
    }
}

#[test]
fn fig7_absolute_gmeans_pinned() {
    // Coarser pins (×/÷1.5) on the per-accelerator gmean magnitudes: a
    // uniform scale error (e.g. a broken τ or static-power term) shifts
    // every ratio equally and would slip past the ratio pins.
    let fps = fig7_grid(|r| r.fps);
    let fpsw = fig7_grid(|r| r.fps_per_w);
    for (name, fps_pin, fpsw_pin) in [
        ("OXBNN_5", 42_702.0, 6_876.0),
        ("OXBNN_50", 471_497.0, 7_745.0),
        ("ROBIN_EO", 5_071.0, 136.7),
        ("ROBIN_PO", 5_366.0, 448.3),
        ("LIGHTBULB", 11_862.0, 264.7),
    ] {
        for (grid, pin, metric) in
            [(&fps, fps_pin, "gmean FPS"), (&fpsw, fpsw_pin, "gmean FPS/W")]
        {
            let measured = gmean(row(grid, name));
            let lo = pin / 1.5;
            let hi = pin * 1.5;
            assert!(
                measured >= lo && measured <= hi,
                "{} {}: measured {:.1} outside pinned [{:.1}, {:.1}]",
                name,
                metric,
                measured,
                lo,
                hi
            );
        }
    }
    assert_eq!(fps.len(), NAMES.len());
}

#[test]
fn table2_scalability_shapes_match_paper() {
    let solver = ScalabilitySolver::default();
    let rows = solver.table2();
    assert_eq!(rows.len(), PAPER_TABLE2.len());
    let mut n_exact = 0;
    let mut last_n = usize::MAX;
    let mut last_p = f64::NEG_INFINITY;
    for (row, &(dr, p_paper, n_paper, gamma_paper, alpha_paper)) in
        rows.iter().zip(PAPER_TABLE2.iter())
    {
        assert_eq!(row.dr_gsps, dr);
        if row.n == n_paper {
            n_exact += 1;
        }
        // Scalability trade-off shapes (Eqs. 3–5): higher DR relaxes the
        // PD sensitivity floor and shrinks the feasible XPE size.
        assert!(row.n <= last_n, "N must be non-increasing in DR");
        assert!(
            row.p_pd_opt_dbm >= last_p - 1e-9,
            "P_PD-opt must relax (grow) with DR"
        );
        assert!(
            (row.p_pd_opt_dbm - p_paper).abs() < 1.0,
            "DR {}: P_PD-opt {:.2} dBm vs paper {:.2}",
            dr,
            row.p_pd_opt_dbm,
            p_paper
        );
        // α = ⌊γ/N⌋ consistency against the paper's own γ column.
        assert_eq!(gamma_paper / n_paper as u64, alpha_paper, "DR {}", dr);
        assert_eq!(row.alpha, row.gamma / row.n as u64, "DR {}", dr);
        last_n = row.n;
        last_p = row.p_pd_opt_dbm;
    }
    assert!(
        n_exact >= 6,
        "Table II N reproduction regressed: {}/{} rows exact",
        n_exact,
        rows.len()
    );
}

// ---------------------------------------------------------------------------
// Event-domain conformance, sequential AND pipelined
// ---------------------------------------------------------------------------

/// Scaled-down OXBNN (PCA) and ROBIN-style (psum-reduction) configs the
/// event simulator can sweep in test time.
fn small_pca() -> AcceleratorConfig {
    let mut cfg = AcceleratorConfig::oxbnn_5();
    cfg.n = 9;
    cfg.xpe_total = 18;
    cfg
}

fn small_reduction() -> AcceleratorConfig {
    let mut cfg = small_pca();
    cfg.name = "SMALL_RED".into();
    cfg.bitcount = BitcountMode::Reduction { latency_s: 3.125e-9, psum_bits: 16 };
    cfg.energy = oxbnn::energy::power::EnergyModel::robin();
    cfg
}

fn tiny_workload() -> Workload {
    use oxbnn::mapping::layer::ConvGeom;
    Workload::new(
        "tiny_conformance",
        vec![
            GemmLayer::new("c1", 16, 243, 8).with_geom(ConvGeom::new(3, 1, 1, 4)),
            GemmLayer::new("c2", 16, 288, 8)
                .with_geom(ConvGeom::new(3, 1, 1, 4))
                .with_pool(),
            GemmLayer::fc("fc", 512, 10),
        ],
    )
}

fn event_report(cfg: &AcceleratorConfig, batch: usize, pipelined: bool) -> Report {
    Session::builder()
        .accelerator(cfg.clone())
        .workload(tiny_workload())
        .backend(BackendKind::Event)
        .batch(batch)
        .pipeline(pipelined)
        .build()
        .expect("event conformance session")
        .run()
}

#[test]
fn event_domain_claims_hold_sequential_and_pipelined() {
    let wl = tiny_workload();
    let expect_passes: u64 =
        wl.layers.iter().map(|l| l.total_passes(9) as u64).sum();
    for pipelined in [false, true] {
        let mode = if pipelined { "pipelined" } else { "sequential" };
        let pca = event_report(&small_pca(), 1, pipelined);
        let red = event_report(&small_reduction(), 1, pipelined);
        // Transaction conservation and the paper's psum headline.
        assert_eq!(pca.passes, expect_passes, "{}: PCA pass count", mode);
        assert_eq!(red.passes, expect_passes, "{}: reduction pass count", mode);
        assert_eq!(pca.psums, 0, "{}: PCA emits no electrical psums", mode);
        assert!(red.psums > 0, "{}: reduction must pay the psum path", mode);
        // Fig. 5/7 story in the event domain: the PCA design is faster and
        // cheaper on the same fabric.
        assert!(
            pca.frame_latency_s < red.frame_latency_s,
            "{}: PCA {} vs reduction {}",
            mode,
            pca.frame_latency_s,
            red.frame_latency_s
        );
        assert!(
            pca.dynamic_energy_per_frame_j < red.dynamic_energy_per_frame_j,
            "{}: PCA energy must be lower",
            mode
        );
        // No modeling-error clamps in either event space.
        for r in [&pca, &red] {
            let clamped: u64 =
                r.layers.iter().map(|l| l.counter("clamped_events")).sum();
            assert_eq!(clamped, 0, "{}: past-time scheduling clamps", mode);
        }
    }
}

#[test]
fn event_pipelined_mode_agrees_with_sequential_and_wins_batched() {
    let seq = event_report(&small_pca(), 4, false);
    let pipe = event_report(&small_pca(), 4, true);
    // Same per-frame transaction multiset either way.
    assert_eq!(pipe.passes, seq.passes);
    assert_eq!(pipe.psums, seq.psums);
    // Cross-layer overlap: first frame no slower; multi-frame overlap:
    // batched throughput strictly better than the sequential multiply.
    assert!(pipe.frame_latency_s <= seq.frame_latency_s * (1.0 + 1e-9));
    assert!(
        pipe.batched_fps() > seq.batched_fps(),
        "pipelined batched FPS {} must beat sequential {}",
        pipe.batched_fps(),
        seq.batched_fps()
    );
}

/// ISSUE-9 scale-out conformance. On the paper's flagship pairing
/// (vgg_small on OXBNN_50), K-chip VDP-split batched FPS is monotone
/// non-decreasing in K with parallel efficiency ≤ 1 (sharding can never
/// conjure super-linear throughput: per-chip queue lengths are ceilings
/// and the link only ever adds time). On an event-simulable geometry the
/// sharded event space lands within a factor of two of the
/// `ShardPlan` closed-form batched-FPS estimate. The single-chip Fig. 7
/// and Table II pins above are untouched by sharding.
#[test]
fn scaleout_fps_scaling_is_monotone_and_analytically_consistent() {
    use oxbnn::arch::workload_sim::simulate_frames_sharded;
    use oxbnn::plan::{ShardPlan, ShardPolicy};
    let cfg = AcceleratorConfig::oxbnn_50();
    let wl = Workload::evaluation_set()
        .into_iter()
        .find(|w| w.name == "vgg_small")
        .expect("vgg_small is in the evaluation set");
    let fps_at = |chips: usize| {
        Session::builder()
            .accelerator(cfg.clone())
            .workload(wl.clone())
            .backend(BackendKind::Analytic)
            .batch(8)
            .pipeline(true)
            .chips(chips)
            .shard_policy(ShardPolicy::VdpSplit)
            .build()
            .expect("sharded conformance session")
            .run()
            .batched_fps()
    };
    let f1 = fps_at(1);
    assert!(f1 > 0.0 && f1.is_finite());
    let mut last = f1;
    for k in [2usize, 4] {
        let fk = fps_at(k);
        assert!(
            fk >= last,
            "FPS must be monotone in chips: K={} gives {} < {}",
            k,
            fk,
            last
        );
        let efficiency = fk / (k as f64 * f1);
        assert!(
            efficiency <= 1.0 + 1e-9,
            "K={}: super-linear scaling efficiency {:.3}",
            k,
            efficiency
        );
        last = fk;
    }
    // Event-domain agreement with the closed-form estimate on a geometry
    // the transaction simulator can sweep in test time.
    let scfg = small_pca();
    let swl = tiny_workload();
    let policy = oxbnn::api::default_policy(&scfg);
    let batch = 4usize;
    for chips in [2usize, 4] {
        let shard = ShardPlan::compile(&scfg, &swl, policy, chips, ShardPolicy::VdpSplit);
        let trace = simulate_frames_sharded(&shard, batch);
        let event_fps = trace.frames as f64 / trace.batch_latency_s;
        let estimate = shard.analytic_batched_fps(batch);
        let ratio = event_fps / estimate;
        assert!(
            (0.5..=2.0).contains(&ratio),
            "K={}: event batched FPS {:.1} vs analytic estimate {:.1} (ratio {:.2})",
            chips,
            event_fps,
            estimate,
            ratio
        );
    }
}

/// The CI admission matrix runs this suite with `OXBNN_PIPELINE=1` and
/// `=0`: a batched session built WITHOUT an explicit `.pipeline(..)`
/// resolves the env-controlled default, and the claims that must hold in
/// BOTH admission modes — exact transaction conservation, batch latency
/// bounded by the sequential multiply, zero past-time clamps — stay green
/// either way.
#[test]
fn default_batched_mode_conserves_in_both_admission_modes() {
    let cfg = small_pca();
    let default_mode = Session::builder()
        .accelerator(cfg.clone())
        .workload(tiny_workload())
        .backend(BackendKind::Event)
        .batch(4)
        .build()
        .expect("default-mode session")
        .run();
    let seq = event_report(&cfg, 4, false);
    assert_eq!(default_mode.passes, seq.passes);
    assert_eq!(default_mode.psums, seq.psums);
    assert!(
        default_mode.batch_latency_s <= seq.batch_latency_s * (1.0 + 1e-9),
        "default mode {} must not exceed the sequential multiply {}",
        default_mode.batch_latency_s,
        seq.batch_latency_s
    );
    let clamped: u64 = default_mode
        .layers
        .iter()
        .map(|l| l.counter("clamped_events"))
        .sum();
    assert_eq!(clamped, 0);
}
