//! Serving-path integration tests that need NO artifacts directory: the
//! coordinator serves a synthetic in-memory manifest on the offline sim
//! engine. Covers the batched hot path (one executable invocation per cut
//! batch), both batch-cut policies, bounded-queue admission control,
//! router accounting, and shutdown flushing.
//!
//! Only meaningful on the sim engine — with `--features xla-runtime` the
//! synthetic manifest has no HLO files to compile, so the whole file is
//! compiled out.
#![cfg(not(feature = "xla-runtime"))]

use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

use oxbnn::coordinator::{
    synthetic_manifest, synthetic_weights, BatchPolicy, InferenceRequest, Server,
    ServerConfig, SubmitError,
};
use oxbnn::functional::bnn;
use oxbnn::runtime::executable_invocations;
use oxbnn::util::rng::Rng;

/// The executable invocation counter is process-wide, and several tests
/// here depend on timing (execute_delay); run them one at a time.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn random_input(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.f64() as f32 - 0.5).collect()
}

fn req(input: Vec<f32>) -> InferenceRequest {
    InferenceRequest { model: "tiny".into(), input }
}

#[test]
fn synthetic_serving_matches_functional_engine() {
    let _guard = serial();
    let cfg = ServerConfig::synthetic(&["tiny"]);
    let seed = cfg.weight_seed;
    let server = Server::start(cfg).expect("server starts without artifacts");
    let input_len = server.input_len("tiny").expect("model registered");

    let manifest = synthetic_manifest(&["tiny"]);
    let artifact = manifest.get("bnn_tiny").unwrap();
    let weights = synthetic_weights(artifact, seed);

    let mut rng = Rng::new(0x5EED);
    for _ in 0..4 {
        let input = random_input(&mut rng, input_len);
        let resp = server.infer_blocking(req(input.clone())).expect("inference");
        let want = bnn::forward(artifact, &input, &weights);
        assert_eq!(resp.logits, want, "served logits mismatch functional engine");
        assert!(resp.total_s >= resp.execute_s);
        assert!(resp.simulated_photonic_s > 0.0);
    }
    assert_eq!(server.outstanding("tiny"), 0);
    server.shutdown();
}

#[test]
fn deadline_policy_cuts_one_full_batch_with_one_invocation() {
    let _guard = serial();
    let mut cfg = ServerConfig::synthetic(&["tiny"]);
    cfg.policy = BatchPolicy::Deadline;
    cfg.max_batch = 8;
    cfg.max_wait = Duration::from_secs(2);
    let seed = cfg.weight_seed;
    let server = Server::start(cfg).expect("start");
    let input_len = server.input_len("tiny").unwrap();

    let manifest = synthetic_manifest(&["tiny"]);
    let artifact = manifest.get("bnn_tiny").unwrap();
    let weights = synthetic_weights(artifact, seed);

    let before = executable_invocations();
    let mut rng = Rng::new(0xBA7C);
    let inputs: Vec<Vec<f32>> = (0..8).map(|_| random_input(&mut rng, input_len)).collect();
    let rxs: Vec<_> = inputs
        .iter()
        .map(|input| server.submit(req(input.clone())).expect("submit").1)
        .collect();
    // Each reply must carry the logits of ITS OWN frame (catches
    // mis-splits/reorders of the stacked batch output).
    for (input, rx) in inputs.iter().zip(rxs) {
        let resp = rx.recv().expect("reply").expect("ok");
        assert_eq!(resp.logits, bnn::forward(artifact, input, &weights));
    }
    let delta = executable_invocations() - before;
    let m = server.metrics.lock().unwrap().clone();
    assert_eq!(m.completed, 8);
    assert_eq!(
        delta, m.batches,
        "exactly one executable invocation per cut batch"
    );
    // Deadline policy holds sub-max batches until full: the burst of
    // exactly max_batch requests cuts as ONE batch of 8.
    assert_eq!(m.batches, 1, "batch sizes seen: {:?}", m.batch_sizes);
    assert_eq!(m.batch_sizes.get(&8), Some(&1));
    assert_eq!(server.outstanding("tiny"), 0);
    server.shutdown();
}

#[test]
fn deadline_policy_honors_max_wait_for_partial_batches() {
    let _guard = serial();
    let mut cfg = ServerConfig::synthetic(&["tiny"]);
    cfg.policy = BatchPolicy::Deadline;
    cfg.max_batch = 64;
    cfg.max_wait = Duration::from_millis(30);
    let server = Server::start(cfg).expect("start");
    let input_len = server.input_len("tiny").unwrap();
    let mut rng = Rng::new(3);
    // A lone request can never fill the batch; it must still complete
    // once max_wait elapses (the old loop ignored max_wait entirely only
    // via drain_now — under Deadline this is the deadline cut).
    let t0 = Instant::now();
    let resp = server
        .infer_blocking(req(random_input(&mut rng, input_len)))
        .expect("deadline cut releases the lone request");
    let waited = t0.elapsed();
    assert!(
        waited >= Duration::from_millis(25),
        "deadline policy should hold ~max_wait, waited {:?}",
        waited
    );
    assert!(resp.queue_s >= 0.0);
    server.shutdown();
}

#[test]
fn immediate_policy_forms_batches_under_backlog() {
    let _guard = serial();
    let mut cfg = ServerConfig::synthetic(&["tiny"]);
    cfg.policy = BatchPolicy::Immediate;
    cfg.max_batch = 8;
    cfg.execute_delay = Duration::from_millis(30);
    let server = Server::start(cfg).expect("start");
    let input_len = server.input_len("tiny").unwrap();
    let mut rng = Rng::new(7);
    let before = executable_invocations();
    let rxs: Vec<_> = (0..24)
        .map(|_| {
            server
                .submit(req(random_input(&mut rng, input_len)))
                .expect("submit")
                .1
        })
        .collect();
    for rx in rxs {
        rx.recv().expect("reply").expect("ok");
    }
    let delta = executable_invocations() - before;
    let m = server.metrics.lock().unwrap().clone();
    assert_eq!(m.completed, 24);
    assert_eq!(delta, m.batches, "one invocation per cut batch");
    // While the first (possibly small) batch executed for 30ms, the rest
    // of the burst queued up — continuous batching must have cut at least
    // one full batch of 8.
    assert!(m.batch_sizes.contains_key(&8), "sizes: {:?}", m.batch_sizes);
    assert!(m.mean_batch_size() > 1.0, "batching was cosmetic: {:?}", m.batch_sizes);
    assert_eq!(server.outstanding("tiny"), 0);
    server.shutdown();
}

#[test]
fn bounded_queue_rejects_at_admission_and_recovers() {
    let _guard = serial();
    let mut cfg = ServerConfig::synthetic(&["tiny"]);
    cfg.max_batch = 1;
    cfg.queue_depth = 1;
    cfg.execute_delay = Duration::from_millis(200);
    let server = Server::start(cfg).expect("start");
    let input_len = server.input_len("tiny").unwrap();
    let mut rng = Rng::new(11);
    let mut rxs = Vec::new();
    let mut rejected = 0usize;
    for _ in 0..10 {
        match server.submit(req(random_input(&mut rng, input_len))) {
            Ok((_replica, rx)) => rxs.push(rx),
            Err(SubmitError::QueueFull { depth, .. }) => {
                assert_eq!(depth, 1);
                rejected += 1;
            }
            Err(e) => panic!("unexpected submit error: {}", e),
        }
    }
    assert!(
        rejected >= 5,
        "depth-1 queue with a 200ms-per-batch worker must shed a rapid \
         burst of 10 (only {} rejected)",
        rejected
    );
    // Accepted requests still complete, rejected ones never consumed a
    // router slot or a metric.
    let accepted = rxs.len() as u64;
    for rx in rxs {
        rx.recv().expect("reply").expect("ok");
    }
    let m = server.metrics.lock().unwrap().clone();
    assert_eq!(m.completed, accepted);
    assert_eq!(m.rejected, rejected as u64);
    assert_eq!(m.failed, 0);
    assert_eq!(server.outstanding("tiny"), 0, "rejections must not leak load");
    server.shutdown();
}

#[test]
fn router_outstanding_drains_even_when_receivers_are_dropped() {
    let _guard = serial();
    let mut cfg = ServerConfig::synthetic(&["tiny"]);
    cfg.replicas = 2;
    let server = Server::start(cfg).expect("start");
    let input_len = server.input_len("tiny").unwrap();
    let mut rng = Rng::new(13);
    for _ in 0..6 {
        // Regression: completion used to live only in infer_blocking, so
        // submit() callers (and dropped replies) leaked outstanding
        // counts forever, permanently skewing least-loaded routing.
        let (_replica, rx) = server
            .submit(req(random_input(&mut rng, input_len)))
            .expect("submit");
        drop(rx);
    }
    let deadline = Instant::now() + Duration::from_secs(5);
    while server.outstanding("tiny") != 0 {
        assert!(
            Instant::now() < deadline,
            "outstanding stuck at {} — router leak",
            server.outstanding("tiny")
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    server.shutdown();
}

#[test]
fn shutdown_flushes_every_accepted_request() {
    let _guard = serial();
    let mut cfg = ServerConfig::synthetic(&["tiny"]);
    cfg.max_batch = 4;
    cfg.execute_delay = Duration::from_millis(20);
    let server = Server::start(cfg).expect("start");
    let input_len = server.input_len("tiny").unwrap();
    let mut rng = Rng::new(17);
    let metrics = std::sync::Arc::clone(&server.metrics);
    let rxs: Vec<_> = (0..12)
        .map(|_| {
            server
                .submit(req(random_input(&mut rng, input_len)))
                .expect("submit")
                .1
        })
        .collect();
    // Immediate shutdown: every accepted request must still be answered
    // (workers drain their queue and flush the batcher before exiting).
    server.shutdown();
    for rx in rxs {
        let resp = rx.recv().expect("flushed reply").expect("ok");
        assert_eq!(resp.logits.len(), 10);
    }
    let m = metrics.lock().unwrap();
    assert_eq!(m.completed, 12);
    assert_eq!(m.failed, 0);
}

#[test]
fn drain_through_shared_handle_flushes_accepted_requests() {
    let _guard = serial();
    // Regression: shutdown() consumed the Server, so an Arc-shared handle
    // (what the HTTP front-end hands its connection threads) could never
    // drain — dropping the Arc leaked workers and in a cut batch the
    // queued responses with them. drain(&self) must flush everything.
    let mut cfg = ServerConfig::synthetic(&["tiny"]);
    cfg.max_batch = 4;
    cfg.execute_delay = Duration::from_millis(20);
    let server = std::sync::Arc::new(Server::start(cfg).expect("start"));
    let input_len = server.input_len("tiny").unwrap();
    let mut rng = Rng::new(23);
    let rxs: Vec<_> = (0..12)
        .map(|_| {
            server
                .submit(req(random_input(&mut rng, input_len)))
                .expect("submit")
                .1
        })
        .collect();
    server.drain();
    for rx in rxs {
        let resp = rx.recv().expect("flushed reply").expect("ok");
        assert_eq!(resp.logits.len(), 10);
    }
    let m = server.metrics.lock().unwrap().clone();
    assert_eq!(m.completed, 12);
    assert_eq!(m.failed, 0);
    // Post-drain submissions fail cleanly instead of panicking or hanging.
    match server.submit(req(random_input(&mut rng, input_len))) {
        Err(SubmitError::WorkerGone(_)) | Err(SubmitError::UnknownModel(_)) => {}
        other => panic!("submit after drain must fail cleanly, got {:?}", other.map(|_| ())),
    }
    // Idempotent: a second drain (or the consuming shutdown) is a no-op.
    server.drain();
}

#[test]
fn quarantine_flushes_queued_jobs_and_reroutes() {
    let _guard = serial();
    let mut cfg = ServerConfig::synthetic(&["tiny"]);
    cfg.replicas = 2;
    cfg.max_batch = 2;
    cfg.execute_delay = Duration::from_millis(30);
    let server = Server::start(cfg).expect("start");
    let input_len = server.input_len("tiny").unwrap();
    let mut rng = Rng::new(29);
    // Build a backlog spread across both replicas.
    let mut accepted = Vec::new();
    for _ in 0..8 {
        let (replica, rx) = server
            .submit(req(random_input(&mut rng, input_len)))
            .expect("submit");
        accepted.push((replica, rx));
    }
    assert!(accepted.iter().any(|(r, _)| *r == 0));
    // Kill replica 0 mid-load: its accepted jobs must still be answered
    // (the worker flushes its queue before exiting), and all new traffic
    // must land on replica 1.
    assert!(server.quarantine("tiny", 0));
    assert!(!server.quarantine("tiny", 0), "second quarantine is a no-op");
    assert_eq!(server.replicas("tiny"), vec![1]);
    for _ in 0..4 {
        let (replica, rx) = server
            .submit(req(random_input(&mut rng, input_len)))
            .expect("submit after quarantine");
        assert_eq!(replica, 1, "quarantined replica must receive no new traffic");
        accepted.push((replica, rx));
    }
    // Pinned submission to the quarantined replica is refused.
    assert!(matches!(
        server.submit_to(req(random_input(&mut rng, input_len)), 0),
        Err(SubmitError::WorkerGone(_))
    ));
    // Zero loss: every accepted request gets a successful reply.
    for (_, rx) in accepted {
        rx.recv().expect("reply").expect("ok");
    }
    let m = server.metrics.lock().unwrap().clone();
    assert_eq!(m.completed, 12);
    assert_eq!(m.failed, 0);
    assert_eq!(server.outstanding("tiny"), 0);
    server.shutdown();
}

#[test]
fn pinned_submit_serves_on_the_requested_replica() {
    let _guard = serial();
    let mut cfg = ServerConfig::synthetic(&["tiny"]);
    cfg.replicas = 2;
    let server = Server::start(cfg).expect("start");
    let input_len = server.input_len("tiny").unwrap();
    let mut rng = Rng::new(31);
    for replica in [0usize, 1, 1, 0] {
        let rx = server
            .submit_to(req(random_input(&mut rng, input_len)), replica)
            .expect("pinned submit");
        rx.recv().expect("reply").expect("ok");
    }
    assert!(matches!(
        server.submit_to(req(random_input(&mut rng, input_len)), 7),
        Err(SubmitError::WorkerGone(_))
    ));
    assert_eq!(server.outstanding("tiny"), 0);
    server.shutdown();
}

#[test]
fn batched_serving_beats_per_frame_serving() {
    let _guard = serial();
    // Same closed-loop load, only max_batch differs: true batching
    // amortizes the per-invocation dispatch overhead, so achieved
    // throughput must be strictly higher with max_batch=8.
    let fps = |max_batch: usize| -> f64 {
        let mut cfg = ServerConfig::synthetic(&["tiny"]);
        cfg.max_batch = max_batch;
        let server = std::sync::Arc::new(Server::start(cfg).expect("start"));
        let input_len = server.input_len("tiny").unwrap();
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for c in 0..8u64 {
            let server = std::sync::Arc::clone(&server);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(0xF00 + c);
                for _ in 0..40 {
                    server
                        .infer_blocking(req(random_input(&mut rng, input_len)))
                        .expect("ok");
                }
            }));
        }
        for h in handles {
            h.join().expect("client");
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let completed = server.metrics.lock().unwrap().completed;
        assert_eq!(completed, 320);
        assert_eq!(server.outstanding("tiny"), 0);
        match std::sync::Arc::try_unwrap(server) {
            Ok(s) => s.shutdown(),
            Err(_) => panic!("clients joined"),
        }
        completed as f64 / elapsed
    };
    let fps1 = fps(1);
    let fps8 = fps(8);
    assert!(
        fps8 > fps1,
        "batched serving must beat per-frame serving: {:.0} vs {:.0} FPS",
        fps8,
        fps1
    );
}
