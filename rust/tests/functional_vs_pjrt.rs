//! End-to-end cross-validation: the AOT-compiled JAX/Pallas BNN (executed
//! via PJRT) must agree bit-exactly with the independent rust functional
//! engine on the same synthetic weights and inputs.
//!
//! This closes the three-layer loop: L1 Pallas kernel → L2 JAX graph →
//! HLO text → rust PJRT runtime, checked against rust integer arithmetic.

use oxbnn::coordinator::synthetic_weights;
use oxbnn::functional::bnn;
use oxbnn::runtime::{HostTensor, Manifest, Runtime};
use oxbnn::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing; run `make artifacts`");
        None
    }
}

fn check_model(model: &str, frames: usize, seed: u64) {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).expect("manifest");
    let artifact = manifest.get(&format!("bnn_{}", model)).expect("artifact");
    let rt = Runtime::cpu().expect("PJRT");
    let exe = rt.load_artifact(artifact).expect("compile");

    let weights = synthetic_weights(artifact, seed);
    let weight_tensors: Vec<HostTensor> = weights
        .iter()
        .zip(&artifact.args[1..])
        .map(|(bits, spec)| HostTensor::new(spec.shape.clone(), bits.clone()).unwrap())
        .collect();

    let input_len = artifact.args[0].element_count();
    let mut rng = Rng::new(seed ^ 0xF00D);
    for frame in 0..frames {
        let x: Vec<f32> = (0..input_len).map(|_| rng.f64() as f32 - 0.5).collect();
        let mut args = vec![HostTensor::new(artifact.args[0].shape.clone(), x.clone()).unwrap()];
        args.extend(weight_tensors.iter().cloned());
        let pjrt_logits = exe.run(&args).expect("execute").data;
        let rust_logits = bnn::forward(artifact, &x, &weights);
        assert_eq!(
            pjrt_logits, rust_logits,
            "{} frame {}: PJRT vs rust functional mismatch",
            model, frame
        );
    }
}

#[test]
fn tiny_model_bit_exact() {
    check_model("tiny", 4, 0xAB);
}

#[test]
fn small_model_bit_exact() {
    check_model("small", 2, 0xCD);
}

#[test]
fn logits_are_bitcounts_in_range() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).expect("manifest");
    let artifact = manifest.get("bnn_tiny").expect("artifact");
    let rt = Runtime::cpu().expect("PJRT");
    let exe = rt.load_artifact(artifact).expect("compile");
    let weights = synthetic_weights(artifact, 7);
    let mut args = vec![HostTensor::zeros(artifact.args[0].shape.clone())];
    args.extend(
        weights
            .iter()
            .zip(&artifact.args[1..])
            .map(|(b, s)| HostTensor::new(s.shape.clone(), b.clone()).unwrap()),
    );
    let out = exe.run(&args).expect("execute");
    let fc_s = artifact.layers.last().unwrap().s as f32;
    for &z in &out.data {
        assert!(z >= 0.0 && z <= fc_s, "logit {} out of [0, {}]", z, fc_s);
        assert_eq!(z.fract(), 0.0, "bitcount logits must be integers");
    }
}

#[test]
fn weights_are_deterministic_per_seed() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).expect("manifest");
    let artifact = manifest.get("bnn_tiny").expect("artifact");
    let a = synthetic_weights(artifact, 42);
    let b = synthetic_weights(artifact, 42);
    let c = synthetic_weights(artifact, 43);
    assert_eq!(a, b);
    assert_ne!(a, c);
    for (w, spec) in a.iter().zip(&artifact.args[1..]) {
        assert_eq!(w.len(), spec.element_count());
        assert!(w.iter().all(|&v| v == 0.0 || v == 1.0));
    }
}
