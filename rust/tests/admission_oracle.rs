//! Admission-oracle suite (tier-1, ISSUE 5).
//!
//! The pipelined event space admits a consumer VDP once the producer has
//! drained the receptive-field prefix `FramePlan::need_acts` computes in
//! closed form. This suite proves that threshold **exact** — never admits
//! before the true receptive field drained, never waits one activation
//! longer — against an independent naive reference model that scans the
//! im2col window element by element, then replays a full `FrameWorld` run
//! asserting no consumer pass was issued before its oracle threshold.

use oxbnn::arch::accelerator::AcceleratorConfig;
use oxbnn::arch::event_sim::FrameWorld;
use oxbnn::mapping::layer::{ConvGeom, GemmLayer};
use oxbnn::mapping::scheduler::MappingPolicy;
use oxbnn::plan::{ExecutionPlan, FramePlan};
use oxbnn::util::quickcheck::{forall, prop_assert, prop_assert_eq, Config};
use oxbnn::workloads::{zoo, Workload};

/// Naive sliding-window reference: enumerate every element of the
/// consumer VDP's k×k window (stride, padding, bounds), keep the
/// raster-maximal in-bounds input element, and translate it through the
/// producer's flattening (activations per raster position; 2×2 pooling
/// maps input `(r, c)` to the producer block ending at `(2r+1, 2c+1)`).
/// Whole-map (`produced`) whenever geometry is absent or does not chain —
/// the window search is structurally independent of the closed-form
/// `need_acts`.
fn oracle_need(
    consumer: &GemmLayer,
    producer: &GemmLayer,
    produced: usize,
    v: usize,
) -> usize {
    let Some(g) = consumer.geom else {
        return produced;
    };
    let out_hw = g.out_hw();
    let positions = out_hw * out_hw;
    if positions == 0 || consumer.vdp_count() % positions != 0 {
        return produced;
    }
    let per_pos = consumer.vdp_count() / positions;
    let pos = v / per_pos;
    let (r, c) = (pos / out_hw, pos % out_hw);
    let mut last: Option<(usize, usize)> = None;
    for kr in 0..g.kernel {
        for kc in 0..g.kernel {
            let ir = r * g.stride + kr;
            let ic = c * g.stride + kc;
            if ir < g.padding || ic < g.padding {
                continue; // in the top/left padding halo
            }
            let (ir, ic) = (ir - g.padding, ic - g.padding);
            if ir >= g.in_hw || ic >= g.in_hw {
                continue; // in the bottom/right padding halo
            }
            // Raster order == lexicographic (row, col) order, and
            // `Some(x) > None` makes the first hit win.
            if Some((ir, ic)) > last {
                last = Some((ir, ic));
            }
        }
    }
    let Some((mut lr, mut lc)) = last else {
        return produced;
    };
    let prod_positions = match producer.geom {
        Some(pg) => pg.out_hw() * pg.out_hw(),
        None => producer.h,
    };
    if prod_positions == 0 || produced % prod_positions != 0 {
        return produced;
    }
    let per_pos_acts = produced / prod_positions;
    let mut prod_hw = 0usize;
    while prod_hw * prod_hw < prod_positions {
        prod_hw += 1;
    }
    if prod_hw * prod_hw != prod_positions {
        return produced;
    }
    if producer.pool {
        if g.in_hw * 2 != prod_hw {
            return produced;
        }
        // Scan the 2×2 producer block behind the pooled element for its
        // raster-maximal member (rather than reusing the closed form).
        let mut best = (0usize, 0usize);
        for pr in [2 * lr, 2 * lr + 1] {
            for pc in [2 * lc, 2 * lc + 1] {
                if (pr, pc) > best {
                    best = (pr, pc);
                }
            }
        }
        (lr, lc) = best;
    } else if g.in_hw != prod_hw {
        return produced;
    }
    ((lr * prod_hw + lc + 1) * per_pos_acts).min(produced)
}

fn small_cfg(xpes: usize) -> AcceleratorConfig {
    let mut cfg = AcceleratorConfig::oxbnn_5();
    cfg.n = 8;
    cfg.xpe_total = xpes;
    cfg
}

/// Every admission threshold of a two-layer chain equals the naive oracle
/// for random `(kernel, stride, padding, hw)` geometries — including
/// pooled producers and depthwise-style (position, channel) consumers.
#[test]
fn prop_need_acts_is_receptive_field_exact() {
    let cfg = small_cfg(8);
    forall(Config::default().cases(150), |g| {
        let kernel = g.usize_in(1, 5);
        let padding = g.usize_in(0, kernel - 1);
        let stride = g.usize_in(1, 3);
        // in_hw large enough that the padded map fits one kernel window.
        let min_in = kernel.saturating_sub(2 * padding).max(1);
        let in_hw = g.usize_in(min_in.max(2), 14);
        let geom = ConvGeom::new(kernel, stride, padding, in_hw);
        let out = geom.out_hw();
        let pooled = g.bool();
        let prod_hw = if pooled { in_hw * 2 } else { in_hw };
        let k_prev = g.usize_in(1, 4);
        let mut producer =
            GemmLayer::new("p", prod_hw * prod_hw, g.usize_in(1, 40), k_prev);
        if pooled {
            producer = producer.with_pool();
        }
        // Half the time a depthwise-style consumer: one VDP per
        // (position, channel), position-major.
        let consumer = if g.bool() {
            let channels = g.usize_in(1, 3);
            GemmLayer::new("dw", out * out * channels, kernel * kernel, 1)
                .with_geom(geom)
        } else {
            GemmLayer::new("c", out * out, g.usize_in(1, 40), g.usize_in(1, 3))
                .with_geom(geom)
        };
        let wl = Workload::new("prop_oracle", vec![producer.clone(), consumer.clone()]);
        let plan = ExecutionPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal);
        let fp = FramePlan::new(&plan, 1);
        let produced = fp.layer_plan(0).vdp_count();
        let vdps = fp.layer_plan(1).vdp_count();
        for v in [0, vdps / 3, vdps / 2, vdps - 1, g.usize_in(0, vdps - 1)] {
            let need = fp.need_acts(1, v);
            let oracle = oracle_need(&consumer, &producer, produced, v);
            prop_assert_eq(need, oracle)?;
            prop_assert(need >= 1 && need <= produced, "threshold in range")?;
        }
        // "Never waits one activation longer": when the stride tiles the
        // map so the last window touches the last input position, the last
        // VDP needs exactly the whole map — and when it does not (floor
        // output maps), the threshold is genuinely below `produced`.
        let (lr, lc) = geom.last_input_rc(out - 1, out - 1);
        let expect_full = !pooled && lr == in_hw - 1 && lc == in_hw - 1;
        if expect_full {
            prop_assert_eq(fp.need_acts(1, vdps - 1), produced)?;
        }
        Ok(())
    });
}

/// All five workload-zoo models (`vgg_small`, `resnet18`, `mobilenet_v2`,
/// `shufflenet_v2`, and the extended `zoo`) carry window geometry whose
/// compiled admission thresholds match the naive oracle on every layer,
/// and most conv consumers genuinely admit early (strictly below the
/// whole-map wait) — the layers that cannot (branchy flattenings like
/// residual projections) fall back soundly.
#[test]
fn zoo_thresholds_match_oracle_and_admit_early() {
    let cfg = small_cfg(8);
    let mut models = Workload::evaluation_set();
    models.extend([zoo::vgg16(), zoo::vgg19(), zoo::resnet50()]);
    for wl in &models {
        let plan = ExecutionPlan::compile(&cfg, wl, MappingPolicy::PcaLocal);
        let fp = FramePlan::new(&plan, 1);
        let mut conv_consumers = 0usize;
        let mut strictly_early = 0usize;
        for unit in 1..wl.layers.len() {
            let consumer = &wl.layers[unit];
            let producer = &wl.layers[unit - 1];
            let produced = fp.layer_plan(unit - 1).vdp_count();
            let vdps = fp.layer_plan(unit).vdp_count();
            let samples = [0, vdps / 7, vdps / 3, vdps / 2, (2 * vdps) / 3, vdps - 1];
            for v in samples {
                assert_eq!(
                    fp.need_acts(unit, v),
                    oracle_need(consumer, producer, produced, v),
                    "{} layer {} ({}) vdp {}",
                    wl.name,
                    unit,
                    consumer.name,
                    v
                );
            }
            if consumer.geom.is_some() {
                conv_consumers += 1;
                if fp.need_acts(unit, 0) < produced {
                    strictly_early += 1;
                }
            }
        }
        assert!(
            strictly_early * 2 >= conv_consumers,
            "{}: only {}/{} conv consumers admit early",
            wl.name,
            strictly_early,
            conv_consumers
        );
        assert!(strictly_early > 0, "{}: no early admission at all", wl.name);
    }
}

/// Event-replay: run a geometry-carrying conv chain through a full
/// 2-frame `FrameWorld` with admission recording on, then check every
/// recorded pass against the oracle — no consumer pass may have been
/// issued before its receptive field drained.
#[test]
fn frame_world_never_admits_before_oracle_threshold() {
    let cfg = small_cfg(8);
    let wl = Workload::new(
        "replay",
        vec![
            GemmLayer::new("c1", 64, 48, 4).with_geom(ConvGeom::new(3, 1, 1, 8)),
            GemmLayer::new("c2", 64, 48, 2).with_geom(ConvGeom::new(3, 1, 1, 8)),
            GemmLayer::new("c3", 16, 24, 2).with_geom(ConvGeom::new(3, 2, 1, 8)),
            GemmLayer::fc("fc", 32, 6),
        ],
    );
    let plan = ExecutionPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal);
    let fp = FramePlan::new(&plan, 2);
    let mut world = FrameWorld::new(&cfg, &fp);
    world.record_admissions(true);
    let outcome = oxbnn::sim::engine::run(&mut world, fp.event_budget());
    assert!(outcome.completed, "replay run truncated");
    let log = world.admission_log();
    assert!(!log.is_empty(), "no admissions recorded");
    let mut early = 0usize;
    for &(unit, vdp, acts) in log {
        let (unit, vdp, acts) = (unit as usize, vdp as usize, acts as usize);
        let layer = fp.unit_layer(unit);
        assert!(layer > 0, "layer-0 passes have no producer to record");
        let consumer = &wl.layers[layer];
        let producer = &wl.layers[layer - 1];
        let produced = fp.layer_plan(unit - 1).vdp_count();
        let threshold = oracle_need(consumer, producer, produced, vdp);
        assert!(
            acts >= threshold,
            "unit {} vdp {} admitted at {} acts < oracle {}",
            unit,
            vdp,
            acts,
            threshold
        );
        if acts < produced {
            early += 1;
        }
    }
    assert!(
        early > 0,
        "pipelining never admitted a pass before the producer fully drained"
    );
    // The sim's own counters stay clean under recording.
    assert_eq!(outcome.stats.counter("clamped_events"), 0);
}

/// Cross-chip event-replay (ISSUE 9): shard the same conv chain over two
/// chips — both policies — and replay with admission recording on. For a
/// chip-crossing edge the recorded availability is the producer's
/// **arrived** raster prefix (`acts_arrived`, fed only by `LinkArrived`
/// events after link occupancy + hop latency), so `acts >= oracle`
/// proves no consumer pass was ever issued before its receptive field
/// had physically crossed the inter-chip link. The same PR-5 thresholds
/// gate both sides — the log is pass-for-pass the size of the unsharded
/// one.
#[test]
fn sharded_world_never_admits_before_activations_cross_the_link() {
    use oxbnn::plan::{AdmissionMode, ShardPlan, ShardPolicy};
    let cfg = small_cfg(8);
    let wl = Workload::new(
        "replay",
        vec![
            GemmLayer::new("c1", 64, 48, 4).with_geom(ConvGeom::new(3, 1, 1, 8)),
            GemmLayer::new("c2", 64, 48, 2).with_geom(ConvGeom::new(3, 1, 1, 8)),
            GemmLayer::new("c3", 16, 24, 2).with_geom(ConvGeom::new(3, 2, 1, 8)),
            GemmLayer::fc("fc", 32, 6),
        ],
    );
    let plan = ExecutionPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal);
    let base_fp = FramePlan::with_admission(&plan, 2, AdmissionMode::Exact);
    let mut base_world = FrameWorld::new(&cfg, &base_fp);
    base_world.record_admissions(true);
    let base_outcome = oxbnn::sim::engine::run(&mut base_world, base_fp.event_budget());
    assert!(base_outcome.completed, "unsharded replay truncated");
    let base_len = base_world.admission_log().len();
    for policy in ShardPolicy::all() {
        let shard = ShardPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal, 2, policy);
        let fp = FramePlan::for_shard(&shard, 2, AdmissionMode::Exact);
        let mut world = FrameWorld::new(&shard.base, &fp);
        world.record_admissions(true);
        let outcome = oxbnn::sim::engine::run(&mut world, fp.event_budget());
        assert!(outcome.completed, "{:?} sharded replay truncated", policy);
        assert!(world.link_transfers() > 0, "{:?}: link never used", policy);
        let log = world.admission_log();
        assert_eq!(log.len(), base_len, "{:?}: admission count diverged", policy);
        let mut crossing = 0usize;
        for &(unit, vdp, acts) in log {
            let (unit, vdp, acts) = (unit as usize, vdp as usize, acts as usize);
            let layer = fp.unit_layer(unit);
            assert!(layer > 0, "layer-0 passes have no producer to record");
            let consumer = &wl.layers[layer];
            let producer = &wl.layers[layer - 1];
            let produced = fp.layer_plan(unit - 1).vdp_count();
            let threshold = oracle_need(consumer, producer, produced, vdp);
            assert!(
                acts >= threshold,
                "{:?} unit {} vdp {} admitted at {} acts < oracle {}",
                policy,
                unit,
                vdp,
                acts,
                threshold
            );
            if fp.edge_crosses(unit) {
                crossing += 1;
            }
        }
        assert!(
            crossing > 0,
            "{:?}: no admission ever rode a chip-crossing edge",
            policy
        );
        assert_eq!(outcome.stats.counter("clamped_events"), 0);
    }
}

/// Wake-index regression (ISSUE 5 satellite): on a 64-XPE world whose
/// whole second layer lives on one XPE, the entire run performs exactly
/// ONE wake dispatch — the drain that crosses the single waiter's
/// threshold — while >100 activations drain. The pre-index world
/// re-dispatched every idle XPE on every drain (≈ 63 × activations).
#[test]
fn activation_drain_wakes_exactly_the_eligible_waiter() {
    let cfg = small_cfg(64);
    assert_eq!(cfg.m(), 8);
    let wl = Workload::new(
        "wake",
        vec![
            // 128 VDPs: two per XPE under PcaLocal's modular assignment.
            GemmLayer::new("c1", 64, 64, 2).with_geom(ConvGeom::new(3, 1, 1, 8)),
            // One FC VDP, on XPE 0 only, whole-map admission threshold.
            GemmLayer::fc("fc", 512, 1),
        ],
    );
    let plan = ExecutionPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal);
    let fp = FramePlan::new(&plan, 1);
    let mut world = FrameWorld::new(&cfg, &fp);
    let outcome = oxbnn::sim::engine::run(&mut world, fp.event_budget());
    assert!(outcome.completed, "wake run truncated");
    assert_eq!(outcome.stats.counter("activations"), 128 + 1);
    assert_eq!(
        world.wake_dispatches(),
        1,
        "one eligible waiter must cost exactly one dispatch, not O(idle XPEs)"
    );
    assert_eq!(outcome.stats.counter("wake_dispatches"), 1);
    assert_eq!(outcome.stats.counter("clamped_events"), 0);
    // Fetch-side O(woken) pin (ISSUE 10 satellite): a `FetchDone` sweep
    // dispatches only the idle XPEs whose frontier IS the fetched unit.
    // c1's fetch wakes all 64 XPEs; fc's fetch can wake at most the one
    // XPE that exhausted c1 first and moved its frontier to fc. The
    // pre-filter sweep re-dispatched every idle XPE on every fetch
    // (up to ~2 × 64 here).
    assert!(
        world.fetch_wake_dispatches() <= 65,
        "fetch sweeps must dispatch O(woken) XPEs, got {}",
        world.fetch_wake_dispatches()
    );
    assert!(world.fetch_wake_dispatches() >= 64, "c1's fetch must wake the full grid");
}
