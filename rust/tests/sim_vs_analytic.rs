//! Cross-validation of the two performance paths: the event-driven
//! transaction-level simulator and the closed-form analytic model must
//! agree on transaction counts exactly and on compute-bound latency
//! closely (the analytic model folds pipeline-fill into a fixed term).

use oxbnn::api::{BackendKind, Report, Session};
use oxbnn::arch::accelerator::{AcceleratorConfig, BitcountMode};
use oxbnn::arch::event_sim::simulate_layer;
use oxbnn::arch::perf::layer_perf;
use oxbnn::mapping::layer::GemmLayer;
use oxbnn::mapping::scheduler::MappingPolicy;
use oxbnn::workloads::Workload;

fn small(pca: bool, n: usize, xpes: usize) -> AcceleratorConfig {
    let mut cfg = AcceleratorConfig::oxbnn_5();
    cfg.n = n;
    cfg.xpe_total = xpes;
    if !pca {
        cfg.bitcount = BitcountMode::Reduction { latency_s: 3.125e-9, psum_bits: 16 };
        cfg.energy = oxbnn::energy::power::EnergyModel::robin();
    }
    cfg
}

#[test]
fn pass_counts_agree_pca() {
    let layer = GemmLayer::new("t", 24, 123, 6);
    let cfg = small(true, 16, 8);
    let analytic = layer_perf(&cfg, &layer);
    let event = simulate_layer(&cfg, &layer, MappingPolicy::PcaLocal);
    assert_eq!(event.counter("passes"), analytic.passes);
    assert_eq!(event.counter("pca_readouts") as usize, layer.vdp_count());
}

#[test]
fn pass_and_psum_counts_agree_reduction() {
    let layer = GemmLayer::new("t", 24, 123, 6);
    let cfg = small(false, 16, 8);
    let analytic = layer_perf(&cfg, &layer);
    let event = simulate_layer(&cfg, &layer, MappingPolicy::SlicedSpread);
    assert_eq!(event.counter("passes"), analytic.passes);
    assert_eq!(event.counter("psums"), analytic.psums);
}

#[test]
fn compute_bound_latency_within_tolerance() {
    // A compute-bound layer (few psums, PCA): the event sim's end time
    // should sit within 25% of the analytic estimate.
    let layer = GemmLayer::new("t", 64, 160, 4);
    let cfg = small(true, 16, 8);
    let analytic = layer_perf(&cfg, &layer);
    let event = simulate_layer(&cfg, &layer, MappingPolicy::PcaLocal);
    let rel = (event.end_time_s - analytic.latency_s).abs() / analytic.latency_s;
    assert!(
        rel < 0.25,
        "event {} vs analytic {} (rel {:.2})",
        event.end_time_s,
        analytic.latency_s,
        rel
    );
}

#[test]
fn energy_categories_consistent() {
    let layer = GemmLayer::new("t", 16, 96, 4);
    let pca_cfg = small(true, 16, 8);
    let red_cfg = small(false, 16, 8);
    let pca = simulate_layer(&pca_cfg, &layer, MappingPolicy::PcaLocal);
    let red = simulate_layer(&red_cfg, &layer, MappingPolicy::SlicedSpread);
    // Same photonic work (n bits per pass, equal pass counts) → gate
    // energy scales exactly with the per-bit constants (ROBIN's two-MRR
    // gates cost 2x OXBNN's single-MRR OXGs).
    let per_bit_ratio = red_cfg.energy.xnor_j_per_bit / pca_cfg.energy.xnor_j_per_bit;
    let measured_ratio = red.energy_of("oxg") / pca.energy_of("oxg");
    assert!(
        (measured_ratio - per_bit_ratio).abs() < 1e-9,
        "gate energy ratio {} vs {}",
        measured_ratio,
        per_bit_ratio
    );
    // Only the reduction design pays ADC+reduction energy; only the PCA
    // design pays readout energy.
    assert_eq!(pca.energy_of("adc+reduction"), 0.0);
    assert!(red.energy_of("adc+reduction") > 0.0);
    assert!(pca.energy_of("pca") > 0.0);
    assert_eq!(red.energy_of("pca"), 0.0);
}

#[test]
fn analytic_monotone_in_xpe_count() {
    // More XPEs → never slower (analytic model sanity).
    let layer = GemmLayer::new("t", 256, 1152, 32);
    let mut last = f64::INFINITY;
    for xpes in [50, 100, 200, 400, 800] {
        let cfg = small(true, 19, xpes);
        let perf = layer_perf(&cfg, &layer);
        assert!(perf.latency_s <= last + 1e-15);
        last = perf.latency_s;
    }
}

/// Run one layer as a single-layer workload through the unified facade.
fn session_report(cfg: &AcceleratorConfig, layer: &GemmLayer, kind: BackendKind) -> Report {
    Session::builder()
        .accelerator(cfg.clone())
        .workload(Workload::new("probe", vec![layer.clone()]))
        .backend(kind)
        .build()
        .expect("probe session")
        .run()
}

#[test]
fn session_analytic_vs_event_agree_on_vgg_conv_geometry() {
    // The acceptance check for the api facade: VGG-small's conv2 vector
    // geometry (S = 1152 → 128 slices/VDP at N = 9) on a cropped 12×12
    // output map, on a scaled-down OXBNN_5 whose 18 XPEs divide both the
    // XPC size (M = N = 9) and the VDP count (1152) evenly. The analytic
    // and event-driven backends must report identical PASS counts and
    // frame latencies within 5%.
    let layer = GemmLayer::new("vgg_conv2_crop", 144, 1152, 8);
    let cfg = small(true, 9, 18);
    let analytic = session_report(&cfg, &layer, BackendKind::Analytic);
    let event = session_report(&cfg, &layer, BackendKind::Event);
    assert_eq!(analytic.passes, event.passes, "PASS counts must match exactly");
    assert_eq!(analytic.passes, layer.total_passes(9) as u64);
    assert_eq!((analytic.psums, event.psums), (0, 0), "PCA emits no psums");
    let rel = (analytic.frame_latency_s - event.frame_latency_s).abs()
        / analytic.frame_latency_s;
    assert!(
        rel < 0.05,
        "analytic {} vs event {} (rel {:.3})",
        analytic.frame_latency_s,
        event.frame_latency_s,
        rel
    );
}

#[test]
fn session_analytic_vs_event_counts_agree_in_reduction_mode() {
    // Same facade, baseline-style psum-reduction accelerator: PASS and
    // psum transaction counts must agree exactly across backends.
    let layer = GemmLayer::new("t", 24, 123, 6);
    let cfg = small(false, 9, 18);
    let analytic = session_report(&cfg, &layer, BackendKind::Analytic);
    let event = session_report(&cfg, &layer, BackendKind::Event);
    assert_eq!(analytic.passes, event.passes);
    assert_eq!(analytic.psums, event.psums);
    assert!(analytic.psums > 0, "reduction mode must pay the psum path");
}

#[test]
fn session_functional_agrees_with_analytic_and_is_clean() {
    // The functional backend carries correctness and delegates timing to
    // the analytic model — through the facade the two must report the
    // same latency and transaction counts.
    let layer = GemmLayer::new("t", 24, 123, 6);
    let cfg = small(true, 9, 18);
    let analytic = session_report(&cfg, &layer, BackendKind::Analytic);
    let functional = session_report(&cfg, &layer, BackendKind::Functional);
    assert_eq!(functional.frame_latency_s, analytic.frame_latency_s);
    assert_eq!(functional.passes, analytic.passes);
    let c = functional.correctness.expect("functional carries correctness");
    assert!(c.vdps_checked > 0);
    assert_eq!(c.mismatches, 0);
}

#[test]
fn full_multilayer_workload_event_vs_analytic_at_scale() {
    // PR-3 satellite: event-vs-analytic agreement on a full multi-layer
    // workload at realistic scale (hundreds of thousands of PASSes), not
    // just single small layers. VGG-family vector geometries (S = 1152 /
    // 2304 → 128 / 256 slices per VDP at N = 9) with VDP counts that
    // divide the 18 XPEs evenly, plus a deliberately unbalanced FC tail.
    let cfg = small(true, 9, 18);
    let wl = Workload::new(
        "vgg_crop_stack",
        vec![
            GemmLayer::new("conv2", 144, 1152, 8),  // 1152 VDPs × 128 slices
            GemmLayer::new("conv3", 72, 1152, 16),  // 1152 VDPs × 128 slices
            GemmLayer::new("conv4", 36, 2304, 32),  // 1152 VDPs × 256 slices
            GemmLayer::fc("fc", 2048, 10),          // 10 VDPs × 228 slices
        ],
    );
    let run = |kind| {
        Session::builder()
            .accelerator(cfg.clone())
            .workload(wl.clone())
            .backend(kind)
            .build()
            .expect("scale session")
            .run()
    };
    let analytic = run(BackendKind::Analytic);
    let event = run(BackendKind::Event);

    // Exact transaction counts on both models, whole frame and per layer.
    let expect_passes: u64 = wl.layers.iter().map(|l| l.total_passes(9) as u64).sum();
    assert!(expect_passes > 500_000, "this test must exercise real scale");
    assert_eq!(analytic.passes, expect_passes);
    assert_eq!(event.passes, expect_passes);
    assert_eq!((analytic.psums, event.psums), (0, 0), "PCA emits no psums");
    for (lr, l) in event.layers.iter().zip(&wl.layers) {
        assert_eq!(lr.passes, l.total_passes(9) as u64, "layer {}", lr.name);
    }

    // Exactly one PCA readout and one activation per VDP (γ is healthy,
    // so no mid-VDP readouts inflate the count).
    let vdps: u64 = wl.layers.iter().map(|l| l.vdp_count() as u64).sum();
    let readouts: u64 = event.layers.iter().map(|l| l.counter("pca_readouts")).sum();
    let activations: u64 = event.layers.iter().map(|l| l.counter("activations")).sum();
    let mid: u64 = event.layers.iter().map(|l| l.counter("mid_vdp_readouts")).sum();
    assert_eq!(readouts, vdps);
    assert_eq!(activations, vdps);
    assert_eq!(mid, 0);
    // No event may ever be scheduled into the past at scale — the clamp
    // counter doubles as the debug-time tripwire for modeling errors.
    let clamped: u64 = event.layers.iter().map(|l| l.counter("clamped_events")).sum();
    assert_eq!(clamped, 0, "past-time scheduling clamps detected");

    // Frame latency within 5% of the closed-form model.
    let rel = (analytic.frame_latency_s - event.frame_latency_s).abs()
        / analytic.frame_latency_s;
    assert!(
        rel < 0.05,
        "analytic {} vs event {} (rel {:.3})",
        analytic.frame_latency_s,
        event.frame_latency_s,
        rel
    );
}

#[test]
fn plan_aware_analytic_narrows_error_on_unbalanced_fc_tail() {
    // PR-4 satellite: the planless analytic model assumes perfect per-XPE
    // balance (`ceil(passes / XPEs)`), which overestimates FPS when a
    // small FC tail leaves most XPEs idle (10 VDPs on 18 XPEs: one XPE
    // serializes a whole VDP's slices). The plan-aware Session path reads
    // the compiled per-XPE queues and must land closer to the event
    // simulator on an FC-dominated workload.
    let cfg = small(true, 9, 18);
    let wl = Workload::new(
        "fc_tail_stack",
        vec![
            GemmLayer::new("c1", 36, 243, 8), // 288 VDPs × 27 slices, balanced
            GemmLayer::fc("fc1", 4096, 10),   // 10 VDPs × 456 slices, unbalanced
            GemmLayer::fc("fc2", 2048, 10),   // 10 VDPs × 228 slices, unbalanced
        ],
    );
    let naive = oxbnn::arch::perf::workload_perf(&cfg, &wl);
    let run = |kind| {
        Session::builder()
            .accelerator(cfg.clone())
            .workload(wl.clone())
            .backend(kind)
            .build()
            .expect("fc tail session")
            .run()
    };
    let plan_aware = run(BackendKind::Analytic);
    let event = run(BackendKind::Event);

    // Same transactions everywhere; the disagreement is purely timing.
    assert_eq!(plan_aware.passes, event.passes);
    let fps_err = |fps: f64| (fps - event.fps).abs() / event.fps;
    let err_naive = fps_err(1.0 / naive.frame_latency_s);
    let err_plan = fps_err(plan_aware.fps);
    assert!(
        err_plan < err_naive,
        "per-XPE imbalance correction must narrow the FPS error: \
         plan-aware {:.4} vs naive {:.4} (event {:.1} FPS)",
        err_plan,
        err_naive,
        event.fps
    );
    assert!(
        err_plan < 0.10,
        "plan-aware analytic still off by {:.3} from the event sim",
        err_plan
    );
    // The correction matters on this workload: the naive model is
    // measurably optimistic (it under-reports the serialized FC tails).
    assert!(
        naive.frame_latency_s < event.frame_latency_s,
        "naive {} vs event {}",
        naive.frame_latency_s,
        event.frame_latency_s
    );
}

#[test]
fn fig5_mapping_gap_grows_with_slices() {
    // The more slices per VDP, the bigger the PCA's advantage over the
    // psum-reduction design — the core Fig. 5 story.
    let cfg_pca = small(true, 9, 4);
    let cfg_red = small(false, 9, 4);
    let mut last_ratio = 0.0;
    for s in [9, 45, 90, 180] {
        let layer = GemmLayer::new("t", 8, s, 2);
        let pca = simulate_layer(&cfg_pca, &layer, MappingPolicy::PcaLocal);
        let red = simulate_layer(&cfg_red, &layer, MappingPolicy::SlicedSpread);
        let ratio = red.end_time_s / pca.end_time_s;
        assert!(
            ratio >= last_ratio * 0.8,
            "S={}: ratio {} vs last {}",
            s,
            ratio,
            last_ratio
        );
        last_ratio = ratio;
    }
    assert!(last_ratio > 1.0, "reduction design must be slower at many slices");
}
