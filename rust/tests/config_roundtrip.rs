//! Config round-trip property: `config::from_json(config::to_json(cfg))`
//! is the identity on every serialized field — for the five evaluation
//! configs (both `BitcountMode` variants) and for randomly perturbed
//! configs drawn by the in-repo quickcheck substrate.

use oxbnn::arch::accelerator::{AcceleratorConfig, BitcountMode};
use oxbnn::config;
use oxbnn::util::quickcheck::{forall, prop_assert, prop_assert_eq, Config};

/// Field-by-field identity over everything `to_json` serializes.
fn assert_identity(cfg: &AcceleratorConfig) {
    let back = config::from_json(&config::to_json(cfg)).expect("round-trip parse");
    assert_eq!(back.name, cfg.name);
    assert_eq!(back.dr_gsps, cfg.dr_gsps);
    assert_eq!(back.n, cfg.n);
    assert_eq!(back.xpe_total, cfg.xpe_total);
    assert_eq!(back.bitcount, cfg.bitcount);
    assert_eq!(back.mem_bw_bits_per_s, cfg.mem_bw_bits_per_s);
    let (a, b) = (&back.energy, &cfg.energy);
    assert_eq!(a.xnor_j_per_bit, b.xnor_j_per_bit);
    assert_eq!(a.receiver_j_per_pass, b.receiver_j_per_pass);
    assert_eq!(a.pca_readout_j, b.pca_readout_j);
    assert_eq!(a.adc_j_per_psum, b.adc_j_per_psum);
    assert_eq!(a.reduction_j_per_psum, b.reduction_j_per_psum);
    assert_eq!(a.sram_j_per_bit, b.sram_j_per_bit);
    assert_eq!(a.tuning_w_per_mrr, b.tuning_w_per_mrr);
    assert_eq!(a.mrrs_per_gate, b.mrrs_per_gate);
}

#[test]
fn evaluation_set_roundtrips_exactly() {
    let set = AcceleratorConfig::evaluation_set();
    // Both bitcount variants are represented in the evaluation set, so
    // this covers the PCA and the psum-reduction schema branches.
    assert!(set.iter().any(|c| matches!(c.bitcount, BitcountMode::Pca { .. })));
    assert!(set
        .iter()
        .any(|c| matches!(c.bitcount, BitcountMode::Reduction { .. })));
    for cfg in &set {
        assert_identity(cfg);
    }
}

#[test]
fn prop_perturbed_configs_roundtrip() {
    forall(Config::default().cases(120), |g| {
        let set = AcceleratorConfig::evaluation_set();
        let mut cfg = set[g.usize_in(0, set.len() - 1)].clone();
        cfg.name = format!("rand_{}", g.usize_in(0, 99999));
        cfg.dr_gsps = g.usize_in(1, 200) as f64 / 2.0;
        cfg.n = g.usize_in(1, 128);
        cfg.xpe_total = g.usize_in(1, 8192);
        cfg.mem_bw_bits_per_s = g.usize_in(1, 1_000_000) as f64 * 1.1e9;
        cfg.bitcount = if g.bool() {
            BitcountMode::Pca { gamma: g.usize_in(1, 1_000_000) as u64 }
        } else {
            BitcountMode::Reduction {
                latency_s: g.usize_in(1, 100_000) as f64 * 3.7e-12,
                psum_bits: g.usize_in(1, 64) as u32,
            }
        };
        cfg.energy.xnor_j_per_bit = g.usize_in(1, 100_000) as f64 * 1.3e-17;
        cfg.energy.adc_j_per_psum = g.usize_in(0, 100_000) as f64 * 2.9e-15;
        cfg.energy.tuning_w_per_mrr = g.usize_in(0, 10_000) as f64 * 7.7e-7;

        let back =
            config::from_json(&config::to_json(&cfg)).map_err(|e| e.to_string())?;
        prop_assert_eq(back.name.clone(), cfg.name.clone())?;
        prop_assert(back.dr_gsps == cfg.dr_gsps, "dr_gsps drifted")?;
        prop_assert_eq(back.n, cfg.n)?;
        prop_assert_eq(back.xpe_total, cfg.xpe_total)?;
        prop_assert(back.bitcount == cfg.bitcount, "bitcount drifted")?;
        prop_assert(
            back.mem_bw_bits_per_s == cfg.mem_bw_bits_per_s,
            "mem bandwidth drifted",
        )?;
        prop_assert(
            back.energy.xnor_j_per_bit == cfg.energy.xnor_j_per_bit,
            "xnor energy drifted",
        )?;
        prop_assert(
            back.energy.adc_j_per_psum == cfg.energy.adc_j_per_psum,
            "adc energy drifted",
        )?;
        prop_assert(
            back.energy.tuning_w_per_mrr == cfg.energy.tuning_w_per_mrr,
            "tuning power drifted",
        )
    });
}

#[test]
fn roundtrip_survives_text_and_pretty_printing() {
    // The CLI writes configs with to_string_pretty and reads them back
    // with from_json_text; that longer path must be lossless too.
    for cfg in AcceleratorConfig::evaluation_set() {
        let text = config::to_json(&cfg).to_string_pretty();
        let back = config::from_json_text(&text).expect("pretty round-trip");
        assert_eq!(back.bitcount, cfg.bitcount);
        assert_eq!(back.xpe_total, cfg.xpe_total);
        assert_eq!(back.energy.mrrs_per_gate, cfg.energy.mrrs_per_gate);
    }
}
