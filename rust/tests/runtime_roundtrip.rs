//! Integration test: AOT HLO artifacts (python/jax/pallas) load, compile and
//! execute through the rust PJRT runtime, and the numerics match a rust-side
//! XNOR-bitcount oracle exactly.
//!
//! Requires `make artifacts` to have run (skipped with a message otherwise —
//! CI always builds artifacts first via the Makefile).

use oxbnn::runtime::{HostTensor, Manifest, Runtime};
use oxbnn::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing; run `make artifacts`");
        None
    }
}

/// Rust oracle for the XNOR-bitcount GEMM with fused comparator.
fn xnor_gemm_oracle(
    inputs: &[f32],
    weights: &[f32],
    h: usize,
    s: usize,
    k: usize,
    apply_activation: bool,
) -> Vec<f32> {
    let mut out = vec![0.0f32; h * k];
    for i in 0..h {
        for j in 0..k {
            let mut count = 0.0f32;
            for t in 0..s {
                let a = inputs[i * s + t];
                let b = weights[t * k + j];
                count += a * b + (1.0 - a) * (1.0 - b);
            }
            out[i * k + j] = if apply_activation {
                if count > 0.5 * s as f32 {
                    1.0
                } else {
                    0.0
                }
            } else {
                count
            };
        }
    }
    out
}

#[test]
fn xnor_gemm_artifact_matches_rust_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).expect("manifest parses");
    let art = manifest.get("xnor_gemm").expect("xnor_gemm artifact");
    let (h, s) = (art.args[0].shape[0], art.args[0].shape[1]);
    let k = art.args[1].shape[1];

    let rt = Runtime::cpu().expect("PJRT CPU client");
    assert!(rt.device_count() >= 1);
    let exe = rt.load_artifact(art).expect("compile artifact");

    let mut rng = Rng::new(0xA0B1);
    let inputs = rng.bits(h * s);
    let weights = rng.bits(s * k);
    let got = exe
        .run(&[
            HostTensor::new(vec![h, s], inputs.clone()).unwrap(),
            HostTensor::new(vec![s, k], weights.clone()).unwrap(),
        ])
        .expect("execute");

    // aot.py exports xnor_gemm with apply_activation=True.
    let want = xnor_gemm_oracle(&inputs, &weights, h, s, k, true);
    assert_eq!(got.shape, vec![h, k]);
    assert_eq!(got.data, want, "PJRT result must match rust oracle exactly");
}

#[test]
fn xnor_gemm_bench_artifact_raw_counts() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).expect("manifest parses");
    let art = manifest.get("xnor_gemm_bench").expect("bench artifact");
    let (h, s) = (art.args[0].shape[0], art.args[0].shape[1]);
    let k = art.args[1].shape[1];

    let rt = Runtime::cpu().expect("PJRT CPU client");
    let exe = rt.load_artifact(art).expect("compile artifact");

    let mut rng = Rng::new(0xC4FE);
    let inputs = rng.bits(h * s);
    let weights = rng.bits(s * k);
    let got = exe
        .run(&[
            HostTensor::new(vec![h, s], inputs.clone()).unwrap(),
            HostTensor::new(vec![s, k], weights.clone()).unwrap(),
        ])
        .expect("execute");

    let want = xnor_gemm_oracle(&inputs, &weights, h, s, k, false);
    assert_eq!(got.data, want);
    // Counts live in [0, S].
    assert!(got.data.iter().all(|&z| (0.0..=s as f32).contains(&z)));
}

#[test]
fn executable_rejects_bad_args() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let art = manifest.get("xnor_gemm").unwrap();
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_artifact(art).unwrap();
    // Wrong arity.
    assert!(exe.run(&[]).is_err());
    // Wrong shape.
    let bad = HostTensor::zeros(vec![1, 1]);
    let ok = HostTensor::zeros(art.args[1].shape.clone());
    assert!(exe.run(&[bad, ok]).is_err());
}
