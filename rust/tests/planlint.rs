//! Plan-lint mutation suite (ISSUE 7 satellite).
//!
//! Two halves:
//!
//! 1. **Zoo conformance** — every shipped workload, compiled on both
//!    paper accelerators under both mapping policies and verified under
//!    both admission modes, lints with zero `Error` findings. This is
//!    the same matrix the `oxbnn lint` CLI subcommand walks in CI.
//! 2. **Mutations** — corrupting a compiled [`ExecutionPlan`] in a
//!    targeted way (stale view, wrong grid, oversubscribed XPE slots,
//!    corrupt slice table, off-by-one kernel, swapped producer/consumer,
//!    B_PCA overflow) yields exactly the machine-readable [`Code`] the
//!    verifier documents for that corruption, and the lint gate turns
//!    the `Error`-severity ones into a typed [`LintRejection`].

use oxbnn::arch::accelerator::AcceleratorConfig;
use oxbnn::check::planlint::{self, has_errors, Code, Severity};
use oxbnn::coordinator::{synthetic_manifest, workload_from_artifact};
use oxbnn::mapping::layer::GemmLayer;
use oxbnn::mapping::scheduler::MappingPolicy;
use oxbnn::plan::{AdmissionMode, ExecutionPlan, ShardPlan, ShardPolicy};
use oxbnn::workloads::{zoo, Workload};

const POLICIES: [MappingPolicy; 2] = [MappingPolicy::PcaLocal, MappingPolicy::SlicedSpread];

fn admissions() -> [AdmissionMode; 2] {
    [AdmissionMode::Exact, AdmissionMode::RasterHalo(0.125)]
}

/// The model zoo the CLI lints: the paper's four evaluation networks
/// plus the ResNet-50 scaling workload.
fn model_zoo() -> Vec<Workload> {
    let mut models = Workload::evaluation_set();
    models.push(zoo::resnet50());
    models
}

/// A small chain whose every cross-layer edge is receptive-field exact
/// (conv -> conv -> pooled conv -> FC) — the controlled fixture the
/// mutations corrupt. Mirrors the geometry style of the zoo networks.
fn chained() -> Workload {
    Workload::new(
        "chained",
        vec![
            GemmLayer::conv("c1", 8, 2, 3, 4),
            GemmLayer::conv("c2", 8, 4, 3, 4).with_pool(),
            GemmLayer::conv("c3", 4, 4, 3, 2),
            GemmLayer::fc("fc", 32, 10),
        ],
    )
}

fn compile(policy: MappingPolicy) -> ExecutionPlan {
    ExecutionPlan::compile(&AcceleratorConfig::oxbnn_5(), &chained(), policy)
}

/// Every code a mutation below expects, asserted present.
fn assert_code(plan: &ExecutionPlan, code: Code) {
    let findings = planlint::verify(plan);
    assert!(
        findings.iter().any(|f| f.code == code),
        "expected {} among: {:?}",
        code.id(),
        findings.iter().map(|f| f.to_string()).collect::<Vec<_>>()
    );
}

// ---------------------------------------------------------------------
// 1. Zoo conformance
// ---------------------------------------------------------------------

#[test]
fn all_zoo_plans_lint_clean_across_the_full_matrix() {
    let accels = [AcceleratorConfig::oxbnn_5(), AcceleratorConfig::oxbnn_50()];
    let mut plans = 0usize;
    for acc in &accels {
        for model in &model_zoo() {
            for policy in POLICIES {
                let plan = ExecutionPlan::compile(acc, model, policy);
                for admission in admissions() {
                    plans += 1;
                    let findings = planlint::verify_with(&plan, admission);
                    assert!(
                        !has_errors(&findings),
                        "{} x {} [{:?}, {:?}]: {:?}",
                        acc.name,
                        model.name,
                        policy,
                        admission,
                        findings.iter().map(|f| f.to_string()).collect::<Vec<_>>()
                    );
                }
            }
        }
    }
    // 5 models x 2 accelerators x 2 policies x 2 admission modes.
    assert_eq!(plans, 40);
}

#[test]
fn zoo_plans_pass_the_gate() {
    for model in &model_zoo() {
        let plan = ExecutionPlan::compile(
            &AcceleratorConfig::oxbnn_50(),
            model,
            MappingPolicy::PcaLocal,
        );
        planlint::gate(&model.name, &plan).expect("zoo plan must pass the lint gate");
    }
}

// ---------------------------------------------------------------------
// 2. Mutations -> expected codes
// ---------------------------------------------------------------------

#[test]
fn stale_workload_view_is_pl101() {
    let mut plan = compile(MappingPolicy::PcaLocal);
    plan.workload.layers[0].k += 1;
    assert_code(&plan, Code::ViewMismatch);
}

#[test]
fn foreign_grid_slicing_is_pl102() {
    let mut plan = compile(MappingPolicy::PcaLocal);
    plan.layers[0].n += 1; // sliced for an XPE size the accelerator lacks
    assert_code(&plan, Code::GridMismatch);
}

#[test]
fn corrupt_slice_table_is_pl104() {
    let mut plan = compile(MappingPolicy::SlicedSpread);
    // Grow the vector size in BOTH views (so PL101 stays quiet): the
    // compiled slice lengths no longer tile S.
    plan.layers[0].layer.s += 1;
    plan.workload.layers[0].s += 1;
    assert_code(&plan, Code::SliceTableCorrupt);
}

#[test]
fn oversubscribed_xpe_grid_is_pl105_and_gate_refuses() {
    let mut plan = compile(MappingPolicy::PcaLocal);
    assert!(planlint::gate("ok", &plan).is_ok());
    plan.layers[0].xpc_count += 1; // passes land on XPCs that do not exist
    let rej = planlint::gate("bad", &plan).unwrap_err();
    assert!(rej.findings.iter().any(|f| f.code == Code::XpeOversubscribed));
    assert!(rej.to_string().contains("PL105"), "{}", rej);
}

#[test]
fn off_by_one_kernel_is_pl204() {
    let mut plan = compile(MappingPolicy::PcaLocal);
    // Enlarge c2's kernel with padding adjusted so the output map — and
    // therefore every raster-alignment precondition — still holds. The
    // admission thresholds this geometry derives are silently wrong;
    // the channel-chain cross-check (S = kernel^2 x producer channels)
    // is what catches it.
    for view in [&mut plan.layers[1].layer, &mut plan.workload.layers[1]] {
        let g = view.geom.as_mut().expect("c2 carries conv geometry");
        g.kernel = 5;
        g.padding = 2;
    }
    let findings = planlint::verify(&plan);
    assert!(
        findings.iter().any(|f| f.code == Code::GeomGemmMismatch),
        "expected PL204 among: {:?}",
        findings.iter().map(|f| f.to_string()).collect::<Vec<_>>()
    );
    // The corruption is layer-scoped and uniquely attributed.
    let f = findings.iter().find(|f| f.code == Code::GeomGemmMismatch).unwrap();
    assert_eq!(f.layer, Some(1));
    assert_eq!(f.severity, Severity::Error);
}

#[test]
fn swapped_producer_consumer_is_pl205() {
    let clean = compile(MappingPolicy::PcaLocal);
    let baseline = planlint::verify(&clean);
    assert!(
        !baseline.iter().any(|f| f.code == Code::AdmissionFallback),
        "fixture must chain exactly: {:?}",
        baseline.iter().map(|f| f.to_string()).collect::<Vec<_>>()
    );

    // Swap c2 and c3 in BOTH views: per-layer checks stay green, but
    // neither conv chains onto its new producer's output map any more —
    // the linter reports the (sound) whole-map fallback on each edge.
    let mut plan = clean;
    plan.layers.swap(1, 2);
    plan.workload.layers.swap(1, 2);
    let findings = planlint::verify(&plan);
    let fallbacks: Vec<_> =
        findings.iter().filter(|f| f.code == Code::AdmissionFallback).collect();
    assert_eq!(fallbacks.len(), 2, "both swapped edges lose pipelining: {:?}", findings);
    assert!(fallbacks.iter().all(|f| f.severity == Severity::Info));
    // Sound, so still servable — the gate admits it.
    assert!(planlint::gate("swapped", &plan).is_ok());
}

#[test]
fn pca_overflow_is_pl301() {
    // The synthetic serving manifest's deterministic overcap trigger: an
    // FC stage of S = 40 000 > gamma = 8 503 on the default serving
    // accelerator — the same plan `serve-http` refuses with HTTP 422.
    let manifest = synthetic_manifest(&["victim-overcap"]);
    let artifact = manifest.get("bnn_victim-overcap").unwrap();
    let workload = workload_from_artifact(artifact);
    let acc = AcceleratorConfig::oxbnn_50();
    let plan = ExecutionPlan::compile(&acc, &workload, MappingPolicy::PcaLocal);
    let rej = planlint::gate("victim-overcap", &plan).unwrap_err();
    assert!(rej.findings.iter().any(|f| f.code == Code::PcaOverflow));
    assert!(rej.to_string().contains("PL301"), "{}", rej);

    // The same geometry is servable when slices spread across XPEs (a
    // single slice of N = 19 ones always fits gamma).
    let spread = ExecutionPlan::compile(&acc, &workload, MappingPolicy::SlicedSpread);
    let findings = planlint::verify(&spread);
    assert!(
        !findings.iter().any(|f| f.code == Code::PcaOverflow),
        "{:?}",
        findings.iter().map(|f| f.to_string()).collect::<Vec<_>>()
    );
}

// ---------------------------------------------------------------------
// 3. Shard lints (PL4xx, ISSUE 9): zoo conformance + mutations
// ---------------------------------------------------------------------

/// The scale-out half of the CLI lint walk: every zoo model, both paper
/// accelerators, both shard policies, K in {1, 2, 4} — compiled shard
/// plans carry zero `Error` findings and pass the shard gate.
#[test]
fn all_zoo_shard_plans_lint_clean_across_k() {
    let accels = [AcceleratorConfig::oxbnn_5(), AcceleratorConfig::oxbnn_50()];
    let mut plans = 0usize;
    for acc in &accels {
        for model in &model_zoo() {
            for shard_policy in ShardPolicy::all() {
                for chips in [1usize, 2, 4] {
                    plans += 1;
                    let shard = ShardPlan::compile(
                        acc,
                        model,
                        MappingPolicy::PcaLocal,
                        chips,
                        shard_policy,
                    );
                    let findings = planlint::verify_shard(&shard);
                    assert!(
                        !has_errors(&findings),
                        "{} x {} [{:?} K={}]: {:?}",
                        acc.name,
                        model.name,
                        shard_policy,
                        chips,
                        findings.iter().map(|f| f.to_string()).collect::<Vec<_>>()
                    );
                    planlint::gate_shard(&model.name, &shard)
                        .expect("zoo shard plan must pass the gate");
                }
            }
        }
    }
    // 5 models x 2 accelerators x 2 shard policies x 3 chip counts.
    assert_eq!(plans, 60);
}

fn shard(policy: ShardPolicy, chips: usize) -> ShardPlan {
    ShardPlan::compile(
        &AcceleratorConfig::oxbnn_5(),
        &chained(),
        MappingPolicy::PcaLocal,
        chips,
        policy,
    )
}

#[test]
fn shard_stage_map_out_of_range_is_pl401_and_gate_refuses() {
    let mut s = shard(ShardPolicy::LayerPipeline, 2);
    assert!(planlint::gate_shard("ok", &s).is_ok());
    *s.chip_of_layer.last_mut().unwrap() = 5; // chip 5 of a 2-chip group
    let rej = planlint::gate_shard("bad", &s).unwrap_err();
    assert!(rej.findings.iter().any(|f| f.code == Code::ShardCoverage));
    assert!(rej.to_string().contains("PL401"), "{}", rej);
}

#[test]
fn truncated_stage_map_is_pl401() {
    let mut s = shard(ShardPolicy::LayerPipeline, 2);
    s.chip_of_layer.pop(); // a layer with no stage — the model is uncovered
    let findings = planlint::verify_shard(&s);
    assert!(
        findings.iter().any(|f| f.code == Code::ShardCoverage),
        "{:?}",
        findings.iter().map(|f| f.to_string()).collect::<Vec<_>>()
    );
}

#[test]
fn noncontiguous_stage_map_is_pl402() {
    let mut s = shard(ShardPolicy::LayerPipeline, 3);
    // Make the map skip a chip: stages must be contiguous, non-decreasing
    // layer ranges, so a 0 -> 2 jump is a malformed pipeline.
    s.chip_of_layer = vec![0, 2, 2, 2];
    let rej = planlint::gate_shard("jump", &s).unwrap_err();
    assert!(rej.findings.iter().any(|f| f.code == Code::ShardOverlap));
    assert!(rej.to_string().contains("PL402"), "{}", rej);
}

#[test]
fn vdp_split_with_residual_stage_map_is_pl401() {
    let mut s = shard(ShardPolicy::VdpSplit, 2);
    assert!(s.chip_of_layer.is_empty(), "VdpSplit compiles no stage map");
    s.chip_of_layer.push(0); // a stage map on a policy that must not have one
    let findings = planlint::verify_shard(&s);
    assert!(findings.iter().any(|f| f.code == Code::ShardCoverage));
}

#[test]
fn degenerate_link_is_pl403_and_gate_refuses() {
    type LinkMutation = fn(&mut oxbnn::plan::ChipLink);
    let mutations: [LinkMutation; 3] = [
        |l| l.bits_per_s = 0.0,
        |l| l.bits_per_act = 0,
        |l| l.latency_s = f64::NAN,
    ];
    for mutate in mutations {
        let mut s = shard(ShardPolicy::LayerPipeline, 2);
        mutate(&mut s.link);
        let rej = planlint::gate_shard("deadlink", &s).unwrap_err();
        assert!(rej.findings.iter().any(|f| f.code == Code::LinkCapacity));
        assert!(rej.to_string().contains("PL403"), "{}", rej);
    }
}

// ---------------------------------------------------------------------
// 4. The machine-readable surface is stable
// ---------------------------------------------------------------------

#[test]
fn codes_and_severities_are_stable() {
    assert_eq!(Code::ViewMismatch.id(), "PL101");
    assert_eq!(Code::XpeOversubscribed.id(), "PL105");
    assert_eq!(Code::AdmissionCycle.id(), "PL201");
    assert_eq!(Code::AdmissionFallback.id(), "PL205");
    assert_eq!(Code::PcaOverflow.id(), "PL301");
    assert_eq!(Code::PcaCapacityDrift.id(), "PL302");
    assert_eq!(Code::ShardCoverage.id(), "PL401");
    assert_eq!(Code::ShardOverlap.id(), "PL402");
    assert_eq!(Code::LinkCapacity.id(), "PL403");
    assert_eq!(Code::ShardImbalance.id(), "PL404");
    assert_eq!(Code::ShardCoverage.severity(), Severity::Error);
    assert_eq!(Code::ShardImbalance.severity(), Severity::Warning);
    assert_eq!(Code::AdmissionFallback.severity(), Severity::Info);
    assert_eq!(Code::PcaCapacityDrift.severity(), Severity::Warning);
    assert_eq!(Code::PcaOverflow.severity(), Severity::Error);
    assert!(Severity::Info < Severity::Warning && Severity::Warning < Severity::Error);
}
