//! Differential suite for the bit-packed functional engine: `forward_packed`
//! must be BIT-exact against the f32 reference `forward` — same logits,
//! f32-equal, no tolerance — over randomized geometries, tail-mask edge
//! depths, and the five zoo-named model geometries the serving stack uses.
//!
//! The f32 path is the obviously-correct reference (scalar compares over
//! {0,1} floats); the packed path is the production engine (XNOR +
//! `count_ones` over `u64` lanes). Any divergence — a wrong tail mask, a
//! mis-blitted im2col run, an off-by-one in the comparator — shows up as a
//! logits mismatch here.

use oxbnn::functional::{bnn, packed};
use oxbnn::functional::{forward, forward_packed, PackedMatrix, PackedWeights};
use oxbnn::runtime::{ArgSpec, Artifact, LayerDim};
use oxbnn::util::quickcheck::{forall, prop_assert, prop_assert_eq, Config};
use oxbnn::util::rng::Rng;

/// Build a `bnn_forward` artifact the functional engine can run: a chain
/// of SAME-padded stride-1 3×3 convs (each `(out_channels, pool_after)`)
/// followed by one FC layer. The geometry conventions match the serving
/// manifests: conv `h = hw²`, `s = 9·c_in`, `fmap_hw = hw` (pre-pool);
/// fc `{h: 1, s: hw²·c_final, k: classes, fmap_hw: 1}`.
fn artifact_for(
    name: &str,
    input_hw: usize,
    input_c: usize,
    convs: &[(usize, bool)],
    classes: usize,
) -> Artifact {
    let mut args = vec![ArgSpec {
        name: "x".into(),
        shape: vec![1, input_hw, input_hw, input_c],
        dtype: "f32".into(),
    }];
    let mut layers = Vec::new();
    let (mut hw, mut c) = (input_hw, input_c);
    for (li, &(k, pool)) in convs.iter().enumerate() {
        let s = 9 * c;
        layers.push(LayerDim {
            kind: "conv".into(),
            h: hw * hw,
            s,
            k,
            fmap_hw: hw,
        });
        args.push(ArgSpec {
            name: format!("w{}", li),
            shape: vec![s, k],
            dtype: "f32".into(),
        });
        c = k;
        if pool {
            assert_eq!(hw % 2, 0, "pooling needs even hw");
            hw /= 2;
        }
    }
    let fc_s = hw * hw * c;
    layers.push(LayerDim { kind: "fc".into(), h: 1, s: fc_s, k: classes, fmap_hw: 1 });
    args.push(ArgSpec {
        name: format!("w{}", convs.len()),
        shape: vec![fc_s, classes],
        dtype: "f32".into(),
    });
    Artifact {
        name: name.into(),
        kind: "bnn_forward".into(),
        file: std::path::PathBuf::from("<synthetic>"),
        args,
        output_shape: vec![1, classes],
        layers,
        model: Some(name.into()),
        input_hw: Some(input_hw),
        input_channels: Some(input_c),
        num_classes: Some(classes),
        apply_activation: None,
    }
}

/// Random {0,1} weights, one matrix per layer.
fn random_weights(artifact: &Artifact, rng: &mut Rng) -> Vec<Vec<f32>> {
    artifact.layers.iter().map(|l| rng.bits(l.s * l.k)).collect()
}

/// Random real-valued input frame in [-0.5, 0.5) (exercises Eq. 1
/// binarization, not just pre-binarized data).
fn random_input(artifact: &Artifact, rng: &mut Rng) -> Vec<f32> {
    let n = artifact.args[0].element_count();
    (0..n).map(|_| rng.f64() as f32 - 0.5).collect()
}

/// Run both engines on the same frame and assert bit-exact logits.
/// Returns the logits for further shape checks.
fn assert_bit_exact(artifact: &Artifact, x: &[f32], weights: &[Vec<f32>]) -> Vec<f32> {
    let reference = forward(artifact, x, weights);
    let pw = PackedWeights::pack(artifact, weights);
    let got = forward_packed(artifact, x, &pw.refs());
    assert_eq!(
        reference, got,
        "{}: packed logits diverge from f32 reference",
        artifact.name
    );
    got
}

/// The ISSUE's headline invariant: over random geometries (spatial size,
/// channel widths biased toward depth % 64 ∈ {0, 1, 63}, conv count,
/// pooling placement), packed and f32 forward passes agree bit-for-bit.
/// Scratch buffers are REUSED across cases on both sides, so stale state
/// leaking between frames of different shapes would also fail here.
#[test]
fn prop_random_geometries_bit_exact() {
    let mut f32_scratch = bnn::Scratch::default();
    let mut packed_scratch = packed::Scratch::default();
    forall(Config::default().cases(40).seed(0xB17_EAC7), |g| {
        // Even spatial sizes so pooling is always legal.
        let input_hw = *g.choose(&[2usize, 4, 6, 8]);
        // Channel widths that push conv depth s = 9c and fc depth hw²·c
        // across word boundaries: c = 7 → s = 63; c = 64 → s = 576 (9
        // words exact); c = 65 → s = 585 (% 64 == 9, tail word).
        let input_c = *g.choose(&[1usize, 3, 7, 8, 64, 65]);
        let depth = g.usize_in(1, 3);
        let convs: Vec<(usize, bool)> = (0..depth)
            .map(|li| {
                let k = *g.choose(&[1usize, 5, 7, 8, 16, 64]);
                // Pool at most once (hw ≥ 2 must survive), early layer only.
                (k, li == 0 && input_hw >= 4 && g.bool())
            })
            .collect();
        let classes = g.usize_in(2, 12);
        let artifact = artifact_for("prop", input_hw, input_c, &convs, classes);

        let mut rng = Rng::new(0x5EED ^ (input_hw * 31 + input_c) as u64);
        let weights = random_weights(&artifact, &mut rng);
        let x = random_input(&artifact, &mut rng);

        let reference = bnn::forward_with(&artifact, &x, &weights, &mut f32_scratch);
        let pw = PackedWeights::pack(&artifact, &weights);
        let got =
            packed::forward_packed_with(&artifact, &x, &pw.refs(), &mut packed_scratch);
        prop_assert_eq(reference.len(), classes)?;
        prop_assert(
            got == reference,
            &format!(
                "hw {} c {} convs {:?}: packed {:?} != f32 {:?}",
                input_hw, input_c, convs, got, reference
            ),
        )
    });
}

/// End-to-end tail-mask edges: FC-only artifacts whose single VDP depth is
/// just below, exactly at, and just above one packed word (63 / 64 / 65).
#[test]
fn tail_mask_depths_end_to_end() {
    for depth in [63usize, 64, 65] {
        let artifact = artifact_for("fc_only", 1, depth, &[], 10);
        assert_eq!(artifact.layers.last().unwrap().s, depth);
        let mut rng = Rng::new(0xDEB7 + depth as u64);
        let weights = random_weights(&artifact, &mut rng);
        let x = random_input(&artifact, &mut rng);
        let logits = assert_bit_exact(&artifact, &x, &weights);
        assert_eq!(logits.len(), 10);
        // FC logits are raw bitcounts: integers within [0, depth].
        for &z in &logits {
            assert_eq!(z.fract(), 0.0, "depth {}: logit {} not integral", depth, z);
            assert!(z >= 0.0 && z <= depth as f32, "depth {}: logit {}", depth, z);
        }
    }
}

/// Conv-path tail mask: 7 input channels give im2col rows of depth
/// s = 63 — one bit short of a word — through a pooled two-conv chain.
#[test]
fn conv_tail_depth_63_bit_exact() {
    let artifact = artifact_for("conv63", 4, 7, &[(8, true), (5, false)], 10);
    assert_eq!(artifact.layers[0].s, 63);
    let mut rng = Rng::new(0xC063);
    let weights = random_weights(&artifact, &mut rng);
    for _ in 0..3 {
        let x = random_input(&artifact, &mut rng);
        assert_bit_exact(&artifact, &x, &weights);
    }
}

/// The five zoo-named model geometries, shrunk to functional-engine scale
/// (the engine runs kernel-3/stride-1/pool chains; the real zoo layers'
/// strides and kernel mixes live in the analytic model, not here). Names
/// match the serving manifests ("tiny", "small") and the paper's
/// evaluation set; each runs packed-vs-f32 bit-exact on several frames.
#[test]
fn zoo_models_bit_exact() {
    let zoo: [(&str, usize, usize, &[(usize, bool)]); 5] = [
        ("tiny", 4, 3, &[(8, false)]),
        ("small", 8, 3, &[(16, true), (16, false)]),
        ("vgg_small", 8, 3, &[(32, false), (32, true), (64, false), (64, true)]),
        ("resnet18", 8, 3, &[(16, false), (16, false), (32, true), (32, false)]),
        ("mobilenet_v2", 8, 3, &[(24, true), (48, false), (48, true)]),
    ];
    for (name, hw, c, convs) in zoo {
        let artifact = artifact_for(name, hw, c, convs, 10);
        let mut rng = Rng::new(0x200 ^ name.len() as u64);
        let weights = random_weights(&artifact, &mut rng);
        for frame in 0..2 {
            let x = random_input(&artifact, &mut rng);
            let logits = assert_bit_exact(&artifact, &x, &weights);
            assert_eq!(logits.len(), 10, "{} frame {}", name, frame);
        }
    }
}

/// `PackedWeights::pack` is exactly per-layer `PackedMatrix::pack` — the
/// convenience bundle must not reorder or re-shape anything.
#[test]
fn packed_weights_bundle_matches_per_layer_packing() {
    let artifact = artifact_for("bundle", 4, 3, &[(8, true), (16, false)], 10);
    let mut rng = Rng::new(0xB0D1);
    let weights = random_weights(&artifact, &mut rng);
    let bundle = PackedWeights::pack(&artifact, &weights);
    let manual: Vec<PackedMatrix> = weights
        .iter()
        .zip(&artifact.layers)
        .map(|(w, dim)| PackedMatrix::pack(w, dim.s, dim.k))
        .collect();
    assert_eq!(bundle.layers().len(), manual.len());
    let x = random_input(&artifact, &mut rng);
    let via_bundle = forward_packed(&artifact, &x, &bundle.refs());
    let refs: Vec<&PackedMatrix> = manual.iter().collect();
    let via_manual = forward_packed(&artifact, &x, &refs);
    assert_eq!(via_bundle, via_manual);
}

/// A reused `Scratch` carried across frames AND geometries yields the
/// same logits as a fresh one per call (the allocation-free serving
/// contract: no state may leak between frames).
#[test]
fn scratch_reuse_is_stateless() {
    let artifacts = [
        artifact_for("a", 4, 7, &[(8, false)], 10),
        artifact_for("b", 8, 3, &[(16, true), (8, false)], 4),
        artifact_for("c", 2, 65, &[(5, false)], 7),
    ];
    let mut scratch = packed::Scratch::default();
    let mut rng = Rng::new(0x5C7A);
    for artifact in &artifacts {
        let weights = random_weights(artifact, &mut rng);
        let pw = PackedWeights::pack(artifact, &weights);
        for _ in 0..2 {
            let x = random_input(artifact, &mut rng);
            let fresh = forward_packed(artifact, &x, &pw.refs());
            let reused =
                packed::forward_packed_with(artifact, &x, &pw.refs(), &mut scratch);
            assert_eq!(fresh, reused, "{}: scratch reuse changed logits", artifact.name);
        }
    }
}
