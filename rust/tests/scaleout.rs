//! Scale-out differential suite (tier-1, ISSUE 9).
//!
//! A `K = 1` [`ShardPlan`] must be indistinguishable from the unsharded
//! [`ExecutionPlan`] — same compiled structure, same admission
//! thresholds, and an event space that is *bit-identical*: equal
//! per-layer PASS/readout/psum/activation multisets and equal makespan.
//! This suite pins that identity over the zoo (structurally on the
//! full-size models, event-exactly on event-affordable crops that keep
//! each model's layer chain and pool structure), and pins the
//! acceptance criterion that a 4-chip VDP-split group beats a single
//! chip on vgg_small while conserving the event multisets.

use oxbnn::api::{BackendKind, Session};
use oxbnn::arch::accelerator::AcceleratorConfig;
use oxbnn::arch::workload_sim::{
    simulate_frames_pipelined_admission, simulate_frames_sharded_admission, PipelineTrace,
};
use oxbnn::mapping::layer::GemmLayer;
use oxbnn::mapping::scheduler::MappingPolicy;
use oxbnn::plan::{AdmissionMode, ExecutionPlan, FramePlan, ShardPlan, ShardPolicy};
use oxbnn::workloads::{zoo, Workload};

fn small_cfg() -> AcceleratorConfig {
    let mut cfg = AcceleratorConfig::oxbnn_5();
    cfg.n = 9;
    cfg.xpe_total = 18;
    cfg
}

/// The five zoo models: the paper's evaluation set plus ResNet-50.
fn zoo_models() -> Vec<Workload> {
    let mut models = Workload::evaluation_set();
    models.push(zoo::resnet50());
    models
}

/// Event-affordable stand-in for a zoo model: the same layer chain and
/// pool structure with maps and channel counts divided down. Geometry is
/// dropped, so admission falls back to the sound whole-map threshold —
/// the geometry-exact cross-chip path is covered by the admission-oracle
/// suite.
fn crop(wl: &Workload, layers: usize) -> Workload {
    let cropped = wl
        .layers
        .iter()
        .take(layers)
        .map(|l| {
            let mut c = GemmLayer::new(
                l.name.clone(),
                (l.h / 64).max(4),
                (l.s / 8).max(4),
                (l.k / 8).max(1),
            );
            if l.pool {
                c = c.with_pool();
            }
            c
        })
        .collect();
    Workload::new(format!("{}_crop", wl.name), cropped)
}

fn layer_counters(t: &PipelineTrace) -> Vec<(String, [u64; 5])> {
    t.layers
        .iter()
        .map(|l| {
            (
                l.name.clone(),
                [l.passes, l.pca_readouts, l.mid_vdp_readouts, l.psums, l.activations],
            )
        })
        .collect()
}

const ADMISSIONS: [AdmissionMode; 2] =
    [AdmissionMode::Exact, AdmissionMode::RasterHalo(0.125)];

/// On every full-size zoo model, both shard policies, both admission
/// modes: the K=1 shard plan compiles the identical layer structure and
/// drives a [`FramePlan`] with identical units, identical admission
/// thresholds, and no cross-chip edges — the structural half of event
/// identity (the event world is a deterministic function of the frame
/// plan).
#[test]
fn k1_shard_plan_is_structurally_identical_on_all_zoo_models() {
    let cfg = AcceleratorConfig::oxbnn_5();
    for wl in &zoo_models() {
        let policy = oxbnn::api::default_policy(&cfg);
        let plan = ExecutionPlan::compile(&cfg, wl, policy);
        for shard_policy in ShardPolicy::all() {
            let shard = ShardPlan::compile(&cfg, wl, policy, 1, shard_policy);
            assert_eq!(shard.chips(), 1);
            assert_eq!(shard.transfers_per_frame(), 0, "{}: K=1 transfers", wl.name);
            for admission in ADMISSIONS {
                let base = FramePlan::with_admission(&plan, 1, admission);
                let fp = FramePlan::for_shard(&shard, 1, admission);
                assert_eq!(fp.units(), base.units(), "{}", wl.name);
                assert_eq!(fp.chips(), 1);
                assert_eq!(fp.total_xpes(), base.total_xpes(), "{}", wl.name);
                for u in 0..fp.units() {
                    assert!(!fp.edge_crosses(u), "{} unit {}", wl.name, u);
                    let (a, b) = (fp.layer_plan(u), base.layer_plan(u));
                    assert_eq!(a.vdp_count(), b.vdp_count(), "{} unit {}", wl.name, u);
                    assert_eq!(
                        a.max_queue_len(),
                        b.max_queue_len(),
                        "{} unit {}",
                        wl.name,
                        u
                    );
                    let vdps = a.vdp_count();
                    for v in [0, vdps / 3, vdps / 2, vdps - 1] {
                        assert_eq!(
                            fp.need_acts(u, v),
                            base.need_acts(u, v),
                            "{} unit {} vdp {}",
                            wl.name,
                            u,
                            v
                        );
                    }
                }
            }
        }
    }
}

/// On event-affordable crops of all five zoo models, both shard
/// policies, both admission modes: the K=1 sharded event space is
/// bit-identical to the unsharded one — exact per-layer event multisets
/// (PASSes, PCA readouts, mid-VDP readouts, psums, activations) and
/// exactly equal frame latency and batch makespan.
#[test]
fn k1_shard_is_event_identical_on_zoo_crops() {
    let cfg = small_cfg();
    for wl in zoo_models().iter().map(|w| crop(w, 6)) {
        let plan = ExecutionPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal);
        for shard_policy in ShardPolicy::all() {
            let shard =
                ShardPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal, 1, shard_policy);
            for admission in ADMISSIONS {
                let base = simulate_frames_pipelined_admission(&plan, 2, admission);
                let t = simulate_frames_sharded_admission(&shard, 2, admission);
                let tag = format!("{} [{:?} {:?}]", wl.name, shard_policy, admission);
                assert_eq!(layer_counters(&t), layer_counters(&base), "{}", tag);
                assert_eq!(t.frame_latency_s, base.frame_latency_s, "{}", tag);
                assert_eq!(t.batch_latency_s, base.batch_latency_s, "{}", tag);
                assert_eq!(t.frame_done_s, base.frame_done_s, "{}", tag);
                assert_eq!(t.chips, 1, "{}", tag);
                assert_eq!(t.link_transfers, 0, "{}", tag);
                assert_eq!(t.link_busy_s, 0.0, "{}", tag);
            }
        }
    }
}

/// The headline acceptance criterion: a 4-chip VDP-split group runs
/// vgg_small at strictly higher batched FPS than one chip, with the
/// per-layer work multisets conserved exactly (scale-out moves work, it
/// never invents or drops it).
#[test]
fn four_chip_vdp_split_beats_one_chip_on_vgg_small() {
    let cfg = AcceleratorConfig::oxbnn_50();
    let wl = Workload::evaluation_set()
        .into_iter()
        .find(|w| w.name == "vgg_small")
        .expect("vgg_small is in the evaluation set");
    let run = |chips: usize| {
        Session::builder()
            .accelerator(cfg.clone())
            .workload(wl.clone())
            .backend(BackendKind::Analytic)
            .batch(8)
            .pipeline(true)
            .chips(chips)
            .shard_policy(ShardPolicy::VdpSplit)
            .build()
            .expect("vgg_small session")
            .run()
    };
    let one = run(1);
    let four = run(4);
    assert!(
        four.batched_fps() > one.batched_fps(),
        "4-chip VDP split must beat 1 chip: {} vs {} FPS",
        four.batched_fps(),
        one.batched_fps()
    );
    // Work conservation: identical per-layer multiset sizes.
    assert_eq!(four.passes, one.passes);
    assert_eq!(four.psums, one.psums);
    assert_eq!(four.layers.len(), one.layers.len());
    for (a, b) in four.layers.iter().zip(&one.layers) {
        assert_eq!((a.name.as_str(), a.passes, a.psums), (b.name.as_str(), b.passes, b.psums));
    }
    // The report carries the group breakdown; a 4-chip group burns 4x
    // the static power.
    let shard = four.shard.as_ref().expect("sharded report breakdown");
    assert_eq!((shard.chips, shard.policy.as_str()), (4, "vdp"));
    assert!(shard.link_transfers > 0, "VDP split must cross the link");
    assert!((four.static_power_w - 4.0 * one.static_power_w).abs() < 1e-9);
}

/// The same conservation on the EVENT path: a 4-chip VDP-split crop of
/// vgg_small executes the identical per-layer event multisets and never
/// takes longer than the single chip over a pipelined batch.
#[test]
fn event_vdp_split_conserves_multisets_on_vgg_crop() {
    let cfg = small_cfg();
    let wl = crop(&Workload::evaluation_set()[0], 6);
    let one = ShardPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal, 1, ShardPolicy::VdpSplit);
    let four = ShardPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal, 4, ShardPolicy::VdpSplit);
    let t1 = simulate_frames_sharded_admission(&one, 4, AdmissionMode::Exact);
    let t4 = simulate_frames_sharded_admission(&four, 4, AdmissionMode::Exact);
    assert_eq!(layer_counters(&t4), layer_counters(&t1), "multisets conserved");
    assert!(
        t4.batch_latency_s <= t1.batch_latency_s,
        "4 chips may never be slower: {} vs {}",
        t4.batch_latency_s,
        t1.batch_latency_s
    );
    assert_eq!(t4.chips, 4);
    assert_eq!(t4.chip_busy_s.len(), 4);
    assert!(t4.link_transfers > 0, "cross-chip edges must use the link");
    assert!(t4.link_busy_s > 0.0);
    // Every chip did real work (the modular maps spread VDPs evenly).
    for (c, busy) in t4.chip_busy_s.iter().enumerate() {
        assert!(*busy > 0.0, "chip {} never ran a PASS", c);
    }
    // Idle/occupancy diagnostics stay in range.
    for f in t4.chip_idle_fraction() {
        assert!((0.0..=1.0).contains(&f));
    }
    assert!((0.0..=1.0).contains(&t4.link_occupancy_fraction()));
}

/// Layer-pipeline sharding on the event path: stages execute on their
/// own chips (busy time on every stage), transfers cross the link only
/// at stage boundaries, and the event multisets stay conserved.
#[test]
fn event_layer_pipeline_conserves_multisets_and_stages() {
    let cfg = small_cfg();
    let wl = crop(&Workload::evaluation_set()[1], 6);
    let one =
        ShardPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal, 1, ShardPolicy::LayerPipeline);
    let two =
        ShardPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal, 2, ShardPolicy::LayerPipeline);
    let t1 = simulate_frames_sharded_admission(&one, 3, AdmissionMode::Exact);
    let t2 = simulate_frames_sharded_admission(&two, 3, AdmissionMode::Exact);
    assert_eq!(layer_counters(&t2), layer_counters(&t1), "multisets conserved");
    let expected_transfers: u64 = 3 * two.transfers_per_frame() as u64;
    assert_eq!(t2.link_transfers, expected_transfers);
    assert_eq!(t2.chips, 2);
    for (c, busy) in t2.chip_busy_s.iter().enumerate() {
        assert!(*busy > 0.0, "stage chip {} never ran a PASS", c);
    }
}
