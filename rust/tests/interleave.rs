//! Deterministic-interleaving model-check suite (ISSUE 7 satellite;
//! steal protocol added in ISSUE 10).
//!
//! Exhaustively explores the three serving-path protocols plus the
//! event-sim scheduler's bounded work-stealing handshake under every
//! thread interleaving (bounded only by the schedule cap) and proves:
//!
//! * the faithful protocols hold their invariants on **every** schedule
//!   — each exploration completes uncapped with at least 10 000
//!   distinct schedules, the CI depth floor;
//! * each seeded regression (the pre-fix double-complete, a torn or
//!   unguarded registry publication, a split read-modify-write on the
//!   retry budget, and the three work-stealing races — double-steal,
//!   steal-past-wake, mid-VDP abandonment) is caught with a concrete
//!   replayable schedule.
//!
//! The explorer is dependency-free and single-threaded, so these runs
//! are exactly reproducible; the nightly TSan job covers the real
//! `std::sync` implementations the models abstract.

use oxbnn::check::interleave::Explorer;
use oxbnn::check::protocols::{
    check_budget, check_registry, check_router, check_steal, BudgetBug, RegistryBug,
    RouterBug, StealBug,
};

/// Exhaustive within the default CI schedule cap.
fn ci() -> Explorer {
    Explorer { max_preemptions: usize::MAX, max_schedules: 200_000 }
}

#[test]
fn router_failover_is_exhaustively_clean() {
    // 4 two-step requests racing a quarantine of replica 0:
    // 9!/(2!)^4 = 22 680 schedules, all explored.
    let report = check_router(&ci(), 4, 2, true, None);
    report.assert_clean();
    assert!(!report.capped, "router exploration must finish uncapped");
    assert!(report.schedules >= 10_000, "only {} schedules explored", report.schedules);
}

#[test]
fn registry_epoch_swap_is_exhaustively_clean() {
    // 3 concurrent hot-loads of one name racing 2 resolves, two shared
    // ops each: 10!/(2!)^5 = 113 400 schedules, all explored.
    let report = check_registry(&ci(), 3, 2, None);
    report.assert_clean();
    assert!(!report.capped, "registry exploration must finish uncapped");
    assert!(report.schedules >= 10_000, "only {} schedules explored", report.schedules);
}

#[test]
fn retry_budget_accounting_is_exhaustively_clean() {
    // 2 depositors x 3 deposits racing 2 withdrawers x 2 withdrawals:
    // 10!/(3! 3! 2! 2!) = 25 200 schedules, all explored. The cap is
    // set high enough that clamping never binds, so conservation is
    // checked exactly at quiescence.
    let report = check_budget(&ci(), 2, 3, 2, 2, 20, 1_000, None);
    report.assert_clean();
    assert!(!report.capped, "budget exploration must finish uncapped");
    assert!(report.schedules >= 10_000, "only {} schedules explored", report.schedules);
}

#[test]
fn steal_park_wake_handshake_is_exhaustively_clean() {
    // One producer draining 3 activations racing two parked stealers
    // (thresholds 2 and 3) over a 2-slice and a 1-slice side unit:
    // 50 010 schedules, all explored. Every schedule conserves each
    // stolen VDP's slices exactly once, keeps the mid-VDP PCA charge
    // owned, never claims past a wake, never issues a consumer unit
    // below its threshold, and quiesces with no wake-heap entry
    // orphaned — the guarantees the `FrameWorld` steal integration
    // relies on.
    let report = check_steal(&ci(), &[2, 3], 3, &[2, 1], 4, None);
    report.assert_clean();
    assert!(!report.capped, "steal exploration must finish uncapped");
    assert!(report.schedules >= 10_000, "only {} schedules explored", report.schedules);
}

#[test]
fn every_seeded_regression_is_caught() {
    let fast = Explorer { max_preemptions: usize::MAX, max_schedules: 50_000 };
    let double = check_router(&fast, 2, 2, true, Some(RouterBug::DoubleComplete));
    let v = double.violation.expect("double-complete must underflow outstanding");
    assert!(!v.schedule.is_empty(), "violations carry a replayable schedule");
    assert!(v.message.contains("underflow"), "{}", v.message);

    assert!(
        check_registry(&fast, 2, 2, Some(RegistryBug::TornEntry)).violation.is_some(),
        "a split publication must be observed torn"
    );
    assert!(
        check_registry(&fast, 2, 1, Some(RegistryBug::UnguardedSwap)).violation.is_some(),
        "an unguarded swap must regress the published epoch"
    );
    assert!(
        check_budget(&fast, 2, 2, 0, 0, 0, 1_000, Some(BudgetBug::SplitRmw))
            .violation
            .is_some(),
        "a split read-modify-write must lose a deposit"
    );

    let double = check_steal(&fast, &[2, 2], 2, &[1], 4, Some(StealBug::DoubleSteal));
    let v = double.violation.expect("a split claim must execute the same VDP twice");
    assert!(!v.schedule.is_empty(), "steal violations carry a replayable schedule");
    assert!(v.message.contains("double-steal"), "{}", v.message);
    let past = check_steal(&fast, &[1], 1, &[1, 1], 4, Some(StealBug::StealPastWake));
    let v = past.violation.expect("claiming past the wake must break the stall bound");
    assert!(v.message.contains("stall bound"), "{}", v.message);
    let abandon = check_steal(&fast, &[1], 1, &[2], 4, Some(StealBug::MidVdpAbandon));
    let v = abandon.violation.expect("mid-VDP abandonment must orphan the PCA charge");
    assert!(v.message.contains("abandoned mid-VDP"), "{}", v.message);
}

#[test]
fn preemption_bounding_prunes_but_stays_sound() {
    // With zero preemptions only round-robin-free (run-to-completion)
    // schedules remain: the faithful router still passes, and the
    // explorer reports what the budget pruned.
    let bounded = Explorer { max_preemptions: 0, max_schedules: 200_000 };
    let report = check_router(&bounded, 3, 2, true, None);
    report.assert_clean();
    assert!(report.pruned > 0, "a zero budget must prune preemptive branches");
    assert!(report.schedules > 0);
}
