//! Coordinator integration: start the real server (PJRT workers), push
//! concurrent requests, verify responses against the functional engine,
//! and check metrics plumbing.

use oxbnn::coordinator::{
    synthetic_weights, InferenceRequest, Server, ServerConfig,
};
use oxbnn::functional::bnn;
use oxbnn::runtime::Manifest;
use oxbnn::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing; run `make artifacts`");
        None
    }
}

#[test]
fn serve_tiny_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServerConfig::new(&dir, &["tiny"]);
    let seed = cfg.weight_seed;
    let server = Server::start(cfg).expect("server starts");
    let input_len = server.input_len("tiny").expect("model registered");

    let manifest = Manifest::load(&dir).unwrap();
    let artifact = manifest.get("bnn_tiny").unwrap();
    let weights = synthetic_weights(artifact, seed);

    let mut rng = Rng::new(0x5EED);
    for _ in 0..6 {
        let input: Vec<f32> = (0..input_len).map(|_| rng.f64() as f32 - 0.5).collect();
        let resp = server
            .infer_blocking(InferenceRequest { model: "tiny".into(), input: input.clone() })
            .expect("inference succeeds");
        // Server must return the same logits as the functional engine.
        let want = bnn::forward(artifact, &input, &weights);
        assert_eq!(resp.logits, want, "served logits mismatch functional engine");
        assert!(resp.total_s >= resp.execute_s);
        assert!(resp.simulated_photonic_s > 0.0);
    }
    let m = server.metrics.lock().unwrap().clone();
    assert_eq!(m.completed, 6);
    assert_eq!(m.failed, 0);
    assert!(m.batches >= 1);
    drop(m);
    assert_eq!(server.outstanding("tiny"), 0, "router accounting must drain");
    server.shutdown();
}

#[test]
fn concurrent_submissions_all_complete() {
    let Some(dir) = artifacts_dir() else { return };
    let server = Server::start(ServerConfig::new(&dir, &["tiny"])).expect("start");
    let input_len = server.input_len("tiny").unwrap();
    let mut rng = Rng::new(1);
    // Fire-and-collect: submit all, then await all receivers.
    let rxs: Vec<_> = (0..16)
        .map(|_| {
            let input: Vec<f32> = (0..input_len).map(|_| rng.f64() as f32).collect();
            server
                .submit(InferenceRequest { model: "tiny".into(), input })
                .expect("submit")
                .1
        })
        .collect();
    for rx in rxs {
        let resp = rx.recv().expect("reply").expect("ok");
        assert_eq!(resp.logits.len(), 10);
    }
    let m = server.metrics.lock().unwrap();
    assert_eq!(m.completed, 16);
    // Dynamic batching should have grouped at least some requests.
    assert!(m.mean_batch_size() >= 1.0);
    drop(m);
    // submit() callers (no infer_blocking) must not leak router load.
    assert_eq!(server.outstanding("tiny"), 0, "router accounting must drain");
    server.shutdown();
}

#[test]
fn invalid_requests_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let server = Server::start(ServerConfig::new(&dir, &["tiny"])).expect("start");
    // Unknown model.
    assert!(server
        .submit(InferenceRequest { model: "nope".into(), input: vec![] })
        .is_err());
    // Wrong input length.
    assert!(server
        .submit(InferenceRequest { model: "tiny".into(), input: vec![0.0; 3] })
        .is_err());
    server.shutdown();
}

#[test]
fn multi_replica_serving_balances_and_completes() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = ServerConfig::new(&dir, &["tiny"]);
    cfg.replicas = 3;
    let server = Server::start(cfg).expect("start");
    let input_len = server.input_len("tiny").unwrap();
    let mut rng = Rng::new(2);
    // Burst submit so the router spreads load across replicas.
    let mut seen = std::collections::BTreeSet::new();
    let mut rxs = Vec::new();
    for _ in 0..12 {
        let input: Vec<f32> = (0..input_len).map(|_| rng.f64() as f32).collect();
        let (replica, rx) = server
            .submit(InferenceRequest { model: "tiny".into(), input })
            .expect("submit");
        seen.insert(replica);
        rxs.push(rx);
    }
    for rx in rxs {
        rx.recv().expect("reply").expect("ok");
    }
    assert!(seen.len() >= 2, "burst should hit multiple replicas: {:?}", seen);
    assert_eq!(server.metrics.lock().unwrap().completed, 12);
    server.shutdown();
}

#[test]
fn unknown_model_at_start_fails() {
    let Some(dir) = artifacts_dir() else { return };
    assert!(Server::start(ServerConfig::new(&dir, &["does_not_exist"])).is_err());
}
