//! End-to-end tests of the HTTP serving front-end over real loopback
//! sockets: correctness against the functional engine, replica failover
//! under concurrent load, overload shedding, hot reload, and graceful
//! drain. Everything runs on the synthetic in-memory models, so no
//! artifacts directory is needed.
//!
//! Only meaningful on the sim engine — with `--features xla-runtime` the
//! synthetic manifest has no HLO files to compile, so the whole file is
//! compiled out.
#![cfg(not(feature = "xla-runtime"))]

use std::sync::{Arc, Barrier, Mutex, MutexGuard};
use std::time::Duration;

use oxbnn::coordinator::{synthetic_manifest, synthetic_weights, ServerConfig};
use oxbnn::functional::bnn;
use oxbnn::serving::{
    request_once, serve, HttpConfig, ModelRegistry, RetryPolicy, ServingHandle,
};
use oxbnn::util::json::{path_f64, Json};
use oxbnn::util::rng::Rng;

/// Timing-sensitive tests (execute_delay, drains) run one at a time.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// Boot a front-end over synthetic models on an OS-assigned port.
fn boot(
    mutate: impl FnOnce(&mut ServerConfig),
    retry: RetryPolicy,
    threads: usize,
    models: &[(&str, usize)],
) -> ServingHandle {
    let mut cfg = ServerConfig::synthetic(&[]);
    cfg.max_batch = 4;
    cfg.queue_depth = 64;
    mutate(&mut cfg);
    let registry = Arc::new(ModelRegistry::synthetic(cfg));
    for (name, replicas) in models {
        registry.load(name, *replicas).expect("model loads");
    }
    let http = HttpConfig {
        addr: "127.0.0.1:0".to_string(),
        threads,
        retry,
        ..HttpConfig::default()
    };
    serve(http, registry).expect("front-end binds loopback")
}

fn infer_body(model: &str, input: &[f32]) -> String {
    let as_f64: Vec<f64> = input.iter().map(|&x| x as f64).collect();
    Json::obj(vec![
        ("model", Json::Str(model.to_string())),
        ("input", Json::arr_f64(&as_f64)),
    ])
    .to_string()
}

fn logits_of(body: &[u8]) -> Vec<f32> {
    let j = Json::parse(std::str::from_utf8(body).unwrap()).unwrap();
    j.get("logits")
        .and_then(Json::as_arr)
        .expect("logits array")
        .iter()
        .map(|v| v.as_f64().expect("numeric logit") as f32)
        .collect()
}

/// The full network round-trip — JSON request, lazy parse, shard route,
/// batched engine, JSON response — must reproduce the functional
/// reference engine bit-exactly (f64 JSON text round-trips f32 exactly).
#[test]
fn http_infer_matches_functional_engine() {
    let _guard = serial();
    let handle = boot(|_| {}, RetryPolicy::default(), 4, &[("tiny", 1)]);
    let addr = handle.addr().to_string();

    let seed = ServerConfig::synthetic(&["tiny"]).weight_seed;
    let manifest = synthetic_manifest(&["tiny"]);
    let artifact = manifest.get("bnn_tiny").unwrap();
    let weights = synthetic_weights(artifact, seed);

    let mut rng = Rng::new(0x5EED);
    for _ in 0..3 {
        let input: Vec<f32> = (0..artifact.args[0].element_count())
            .map(|_| rng.f64() as f32 - 0.5)
            .collect();
        let (status, body) =
            request_once(&addr, "POST", "/v1/infer", infer_body("tiny", &input).as_bytes())
                .unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
        let want = bnn::forward(artifact, &input, &weights);
        assert_eq!(logits_of(&body), want, "HTTP logits diverge from functional engine");
        assert!(path_f64(&body, &["latency", "total_s"]).unwrap().unwrap() > 0.0);
    }
    handle.shutdown();
}

/// Kill a replica mid-load: traffic rebalances onto the survivor and no
/// request is silently lost — every submission gets a 200.
#[test]
fn failover_quarantine_rebalances_without_loss() {
    let _guard = serial();
    let handle = boot(
        |cfg| {
            cfg.execute_delay = Duration::from_millis(10);
            cfg.max_batch = 2;
        },
        RetryPolicy { max_retries: 3, backoff: Duration::from_millis(5), ..Default::default() },
        20,
        &[("m", 2)],
    );
    let addr = handle.addr().to_string();
    let entry = handle.registry().get("m").expect("model loaded");
    assert_eq!(entry.server.replicas("m").len(), 2);

    let barrier = Arc::new(Barrier::new(17));
    let mut clients = Vec::new();
    for i in 0..16u64 {
        let addr = addr.clone();
        let barrier = Arc::clone(&barrier);
        let body = infer_body("m", &vec![0.25 + i as f32 * 1e-3; entry.input_len]);
        clients.push(std::thread::spawn(move || {
            barrier.wait();
            request_once(&addr, "POST", "/v1/infer", body.as_bytes())
        }));
    }
    barrier.wait();
    // Let some requests land on both replicas, then kill replica 0.
    std::thread::sleep(Duration::from_millis(5));
    assert!(entry.server.quarantine("m", 0), "replica 0 was live");
    for c in clients {
        let (status, body) = c.join().unwrap().expect("no transport failures");
        assert_eq!(
            status,
            200,
            "request lost to quarantine: {}",
            String::from_utf8_lossy(&body)
        );
    }
    // Traffic rebalanced: only the survivor remains, and it still serves.
    assert_eq!(entry.server.replicas("m"), vec![1]);
    let (status, _) = request_once(
        &addr,
        "POST",
        "/v1/infer",
        infer_body("m", &vec![0.5; entry.input_len]).as_bytes(),
    )
    .unwrap();
    assert_eq!(status, 200);
    assert_eq!(entry.server.outstanding("m"), 0, "router accounting leaked");
    handle.shutdown();
}

/// Overload beyond the bounded queue sheds with 429 (Retry-After) while
/// every request still gets an answer, and the shed counter records it.
#[test]
fn overload_sheds_with_429() {
    let _guard = serial();
    let handle = boot(
        |cfg| {
            cfg.queue_depth = 1;
            cfg.max_batch = 1;
            cfg.execute_delay = Duration::from_millis(50);
        },
        RetryPolicy { max_retries: 0, ..Default::default() },
        20,
        &[("m", 1)],
    );
    let addr = handle.addr().to_string();
    let input_len = handle.registry().get("m").unwrap().input_len;
    let mut clients = Vec::new();
    for _ in 0..16 {
        let addr = addr.clone();
        let body = infer_body("m", &vec![0.1; input_len]);
        clients.push(std::thread::spawn(move || {
            request_once(&addr, "POST", "/v1/infer", body.as_bytes())
        }));
    }
    let (mut ok, mut shed) = (0, 0);
    for c in clients {
        match c.join().unwrap().expect("every request gets a response") {
            (200, _) => ok += 1,
            (429, _) => shed += 1,
            (status, body) => {
                panic!("unexpected {}: {}", status, String::from_utf8_lossy(&body))
            }
        }
    }
    assert!(ok > 0, "some requests must land");
    assert!(shed > 0, "queue depth 1 must shed under 16-way concurrency");
    assert_eq!(ok + shed, 16);
    assert_eq!(handle.metrics().shed(), shed as u64);
    assert_eq!(handle.metrics().count("/v1/infer", 429), shed as u64);
    handle.shutdown();
}

/// Hot reload during serving: the epoch in infer responses bumps, and no
/// request observes an error window.
#[test]
fn hot_reload_bumps_epoch_in_responses() {
    let _guard = serial();
    let handle = boot(|_| {}, RetryPolicy::default(), 4, &[("m", 1)]);
    let addr = handle.addr().to_string();
    let input_len = handle.registry().get("m").unwrap().input_len;
    let body = infer_body("m", &vec![0.3; input_len]);

    let (status, resp) = request_once(&addr, "POST", "/v1/infer", body.as_bytes()).unwrap();
    assert_eq!(status, 200);
    assert_eq!(path_f64(&resp, &["epoch"]).unwrap(), Some(1.0));

    handle.registry().reload("m").expect("hot reload");
    let (status, resp) = request_once(&addr, "POST", "/v1/infer", body.as_bytes()).unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&resp));
    assert_eq!(path_f64(&resp, &["epoch"]).unwrap(), Some(2.0));
    handle.shutdown();
}

/// Graceful drain: requests in flight when shutdown starts all complete
/// with 200 — nothing accepted is lost.
#[test]
fn graceful_drain_completes_in_flight_requests() {
    let _guard = serial();
    let handle = boot(
        |cfg| cfg.execute_delay = Duration::from_millis(100),
        RetryPolicy::default(),
        8,
        &[("m", 1)],
    );
    let addr = handle.addr().to_string();
    let input_len = handle.registry().get("m").unwrap().input_len;
    let barrier = Arc::new(Barrier::new(5));
    let mut clients = Vec::new();
    for i in 0..4u64 {
        let addr = addr.clone();
        let barrier = Arc::clone(&barrier);
        let body = infer_body("m", &vec![0.2 + i as f32 * 1e-3; input_len]);
        clients.push(std::thread::spawn(move || {
            barrier.wait();
            request_once(&addr, "POST", "/v1/infer", body.as_bytes())
        }));
    }
    barrier.wait();
    // Requests are submitted within a few ms and execute for 100ms;
    // drain while they are still inside the engine.
    std::thread::sleep(Duration::from_millis(40));
    handle.shutdown();
    for c in clients {
        let (status, body) = c.join().unwrap().expect("in-flight request dropped");
        assert_eq!(
            status,
            200,
            "in-flight request lost to drain: {}",
            String::from_utf8_lossy(&body)
        );
    }
    assert!(
        request_once(&addr, "GET", "/healthz", b"").is_err(),
        "server must be down after shutdown"
    );
}

/// ISSUE-9 group staging over the wire: a `chips` field in
/// `PUT /v1/models` stages the model onto a K-accelerator shard group
/// that the router serves as ONE replica set, the listing reports the
/// group width, and a group whose plan fails the static lint is refused
/// with 422 exactly like a single-chip load — the shard gate runs the
/// full single-plan lint underneath.
#[test]
fn put_models_chips_field_stages_and_gates_groups() {
    let _guard = serial();
    let handle = boot(|_| {}, RetryPolicy::default(), 4, &[("m", 1)]);
    let addr = handle.addr().to_string();

    let body = br#"{"models": [{"name": "m", "replicas": 1, "chips": 2}]}"#;
    let (status, listing) = request_once(&addr, "PUT", "/v1/models", body).unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&listing));
    let j = Json::parse(std::str::from_utf8(&listing).unwrap()).unwrap();
    let models = j.get("models").and_then(Json::as_arr).unwrap();
    assert_eq!(models[0].get("name").and_then(Json::as_str), Some("m"));
    assert_eq!(models[0].get("chips").and_then(Json::as_usize), Some(2));
    let entry = handle.registry().get("m").expect("group staged");
    assert_eq!(entry.chips, 2);
    assert!(entry.photonic_fps > 0.0 && entry.photonic_fps.is_finite());

    // The group serves inference like any single replica would.
    let (status, resp) = request_once(
        &addr,
        "POST",
        "/v1/infer",
        infer_body("m", &vec![0.4; entry.input_len]).as_bytes(),
    )
    .unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&resp));
    assert_eq!(logits_of(&resp).len(), 10);

    // A lint-failing plan is refused with 422 through the shard gate too,
    // and the refused group is never published.
    let body =
        br#"{"models": [{"name": "m", "chips": 2}, {"name": "bad-overcap", "chips": 2}]}"#;
    let (status, reply) = request_once(&addr, "PUT", "/v1/models", body).unwrap();
    let text = String::from_utf8_lossy(&reply).to_string();
    assert_eq!(status, 422, "{}", text);
    assert!(text.contains("PL301"), "{}", text);
    assert!(!handle.registry().names().contains(&"bad-overcap".to_string()));
    handle.shutdown();
}

/// Error surface: bad JSON, unknown model, wrong method, unknown path,
/// plus the healthy-path health and models pages.
#[test]
fn endpoint_error_surface() {
    let _guard = serial();
    let handle = boot(|_| {}, RetryPolicy::default(), 4, &[("m", 1)]);
    let addr = handle.addr().to_string();

    let (status, _) = request_once(&addr, "POST", "/v1/infer", b"{oops").unwrap();
    assert_eq!(status, 400);
    let (status, _) =
        request_once(&addr, "POST", "/v1/infer", br#"{"input": [1.0]}"#).unwrap();
    assert_eq!(status, 400, "missing model field");
    let (status, _) = request_once(
        &addr,
        "POST",
        "/v1/infer",
        br#"{"model": "ghost", "input": [1.0]}"#,
    )
    .unwrap();
    assert_eq!(status, 404);
    let (status, _) =
        request_once(&addr, "POST", "/v1/infer", br#"{"model": "m", "input": [1.0]}"#)
            .unwrap();
    assert_eq!(status, 400, "wrong input length");
    let (status, _) = request_once(&addr, "DELETE", "/v1/models", b"").unwrap();
    assert_eq!(status, 405);
    let (status, _) = request_once(&addr, "GET", "/v2/nothing", b"").unwrap();
    assert_eq!(status, 404);

    let (status, body) = request_once(&addr, "GET", "/healthz", b"").unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let (status, body) = request_once(&addr, "GET", "/v1/models", b"").unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let models = j.get("models").and_then(Json::as_arr).unwrap();
    assert_eq!(models[0].get("name").and_then(Json::as_str), Some("m"));
    assert!(
        models[0]
            .get("photonic_fps")
            .and_then(Json::as_f64)
            .unwrap()
            > 0.0
    );
    handle.shutdown();
}
