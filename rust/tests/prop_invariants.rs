//! Property-based invariants across the coordinator, mapping and analysis
//! layers (uses the in-repo quickcheck substrate).

use oxbnn::analysis::pca_capacity::{alpha, gamma_calibrated};
use oxbnn::analysis::scalability::ScalabilitySolver;
use oxbnn::arch::accelerator::{AcceleratorConfig, BitcountMode};
use oxbnn::arch::perf::layer_perf;
use oxbnn::arch::workload_sim::{
    simulate_frame_planned, simulate_frames_pipelined,
    simulate_frames_pipelined_admission, simulate_frames_pipelined_opts,
    simulate_frames_sharded_opts,
};
use oxbnn::coordinator::Batcher;
use oxbnn::coordinator::Router;
use oxbnn::mapping::layer::{ConvGeom, GemmLayer};
use oxbnn::mapping::scheduler::MappingPolicy;
use oxbnn::plan::{AdmissionMode, ExecutionPlan, FramePlan, LayerPlan, PassStream};
use oxbnn::util::json::Json;
use oxbnn::util::quickcheck::{forall, prop_assert, prop_assert_eq, Config};
use oxbnn::workloads::Workload;

/// The PR-3 tentpole invariant: for random layers, geometries and both
/// mapping policies, the streaming `LayerPlan`/`PassStream` enumerates
/// exactly the same (XPE, vdp, slice_idx, slice_len) sequence — same
/// multiset AND same per-XPE order — as the independently implemented
/// materialized `Schedule::plan`.
#[test]
fn prop_stream_matches_materialized_schedule() {
    forall(Config::default().cases(80), |g| {
        let layer = GemmLayer::new(
            "p",
            g.usize_in(1, 24),
            g.usize_in(1, 400),
            g.usize_in(1, 12),
        );
        let n = g.usize_in(1, 64);
        let m = g.usize_in(1, 9);
        let xpcs = g.usize_in(1, 4);
        let policy = if g.bool() {
            MappingPolicy::PcaLocal
        } else {
            MappingPolicy::SlicedSpread
        };
        let plan = LayerPlan::compile(&layer, policy, n, m, xpcs);
        let sched = plan.materialize();
        let mut stream = PassStream::new(&plan);
        let mut streamed_total = 0usize;
        for (id, queue) in sched.iter_queues() {
            let flat = plan.flat(id);
            prop_assert_eq(plan.queue_len(flat), queue.len())?;
            // Drain this XPE through the stream: pass-for-pass identical,
            // in order.
            for (k, expect) in queue.iter().enumerate() {
                let got = stream
                    .next_for(&plan, flat)
                    .ok_or_else(|| format!("stream dry at {:?}[{}]", id, k))?;
                prop_assert_eq(got, *expect)?;
                // Random access agrees with sequential streaming.
                prop_assert_eq(plan.pass_at(flat, k), Some(*expect))?;
                streamed_total += 1;
            }
            prop_assert(
                stream.next_for(&plan, flat).is_none(),
                "stream yields beyond the materialized queue",
            )?;
        }
        prop_assert_eq(streamed_total, plan.total_passes())?;
        prop_assert_eq(streamed_total, sched.total_passes())?;
        prop_assert(stream.all_issued(), "all_issued after full drain")?;
        prop_assert_eq(plan.max_queue_len(), sched.max_queue_len())
    });
}

/// The PR-4 tentpole invariants. For random accelerator geometries,
/// workloads, bitcount modes and mapping policies:
///
/// 1. **Conservation** — the whole-frame pipelined event space executes
///    exactly the per-layer transaction multiset of the sequential path.
///    Both paths stream the same compiled per-XPE queues, so equality of
///    the per-layer pass/readout/activation/psum counts (checked per
///    frame-0 unit AND as whole-run totals) pins the full multiset.
/// 2. **No slower** — cross-layer overlap can only shorten a frame:
///    pipelined single-frame latency ≤ sequential frame latency, with
///    zero past-time clamps in either space.
#[test]
fn prop_pipelined_whole_frame_conserves_and_is_no_slower() {
    forall(Config::default().cases(30), |g| {
        let n_layers = g.usize_in(1, 3);
        let layers: Vec<GemmLayer> = (0..n_layers)
            .map(|i| {
                let h = g.usize_in(1, 10);
                let s = g.usize_in(1, 120);
                let k = g.usize_in(1, 5);
                GemmLayer::new(format!("l{}", i), h, s, k)
            })
            .collect();
        let wl = Workload::new("prop_pipe", layers);
        let mut cfg = AcceleratorConfig::oxbnn_5();
        cfg.n = g.usize_in(2, 24);
        cfg.xpe_total = g.usize_in(2, 20);
        let policy;
        if g.bool() {
            // Healthy gamma: saturation dynamics are covered by their own
            // unit tests; this property pins scheduling, not clamping.
            cfg.bitcount = BitcountMode::Pca { gamma: 1 << 20 };
            policy = if g.bool() {
                MappingPolicy::PcaLocal
            } else {
                MappingPolicy::SlicedSpread
            };
        } else {
            cfg.bitcount =
                BitcountMode::Reduction { latency_s: 3.125e-9, psum_bits: 16 };
            cfg.energy = oxbnn::energy::power::EnergyModel::robin();
            policy = MappingPolicy::SlicedSpread;
        }
        let plan = ExecutionPlan::compile(&cfg, &wl, policy);
        let seq = simulate_frame_planned(&plan);
        let pipe = simulate_frames_pipelined(&plan, 1);

        // Whole-run conservation.
        for key in ["passes", "pca_readouts", "activations", "psums"] {
            prop_assert_eq(pipe.stats.counter(key), seq.stats.counter(key))?;
        }
        // Per-layer conservation (frame-0 units vs per-layer plans).
        for (lt, lp) in pipe.layers.iter().zip(&plan.layers) {
            prop_assert_eq(lt.passes, lp.total_passes() as u64)?;
            prop_assert_eq(lt.activations, lp.vdp_count() as u64)?;
        }
        // Zero modeling-error clamps in either event space.
        prop_assert_eq(pipe.stats.counter("clamped_events"), 0)?;
        prop_assert_eq(seq.stats.counter("clamped_events"), 0)?;
        // Cross-layer overlap never hurts the frame.
        prop_assert(
            pipe.frame_latency_s <= seq.frame_latency_s * (1.0 + 1e-9),
            &format!(
                "pipelined frame {} slower than sequential {}",
                pipe.frame_latency_s, seq.frame_latency_s
            ),
        )
    });
}

/// Multi-frame pipelining: for random geometries, an N-frame pipelined
/// batch conserves N× the per-frame transactions and never exceeds the
/// sequential `N · frame` multiply (it strictly beats it whenever the
/// workload leaves XPEs idle, which the dedicated tests and bench pin).
#[test]
fn prop_pipelined_batch_conserves_and_never_exceeds_multiply() {
    forall(Config::default().cases(20), |g| {
        let layers: Vec<GemmLayer> = (0..g.usize_in(1, 3))
            .map(|i| {
                GemmLayer::new(
                    format!("l{}", i),
                    g.usize_in(1, 8),
                    g.usize_in(1, 90),
                    g.usize_in(1, 4),
                )
            })
            .collect();
        let wl = Workload::new("prop_batch", layers);
        let mut cfg = AcceleratorConfig::oxbnn_5();
        cfg.n = g.usize_in(2, 16);
        cfg.xpe_total = g.usize_in(2, 12);
        cfg.bitcount = BitcountMode::Pca { gamma: 1 << 20 };
        let frames = g.usize_in(2, 4);
        let plan = ExecutionPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal);
        let seq = simulate_frame_planned(&plan);
        let pipe = simulate_frames_pipelined(&plan, frames);
        prop_assert_eq(
            pipe.stats.counter("passes"),
            frames as u64 * seq.stats.counter("passes"),
        )?;
        prop_assert_eq(
            pipe.stats.counter("activations"),
            frames as u64 * seq.stats.counter("activations"),
        )?;
        prop_assert_eq(pipe.stats.counter("clamped_events"), 0)?;
        prop_assert(
            pipe.batch_latency_s
                <= frames as f64 * seq.frame_latency_s * (1.0 + 1e-9),
            &format!(
                "pipelined batch {} exceeds sequential multiply {}",
                pipe.batch_latency_s,
                frames as f64 * seq.frame_latency_s
            ),
        )?;
        // Frames drain in order under frame-major priority.
        for w in pipe.frame_done_s.windows(2) {
            prop_assert(w[1] >= w[0] - 1e-12, "frame completions out of order")?;
        }
        Ok(())
    });
}

/// The ISSUE-5 differential: receptive-field-exact admission vs the
/// legacy 12.5% raster halo, on random conv-tail workloads (same-map 3×3
/// stride-1 chains, maps wide enough that the exact one-row lookahead
/// undercuts the halo pointwise, feeding an unbalanced FC tail).
///
/// 1. **Pointwise lemma** — every exact threshold ≤ the halo threshold.
/// 2. **Conservation** — both admission modes execute the identical
///    per-layer PASS/readout/activation/psum multisets (admission defers
///    work, it never changes it).
/// 3. **Makespan** — with pointwise-earlier admission, the single-frame
///    pipelined makespan under exact admission is ≤ the halo makespan
///    (every event time is a monotone function of its release times in
///    PCA mode: serial per-XPE queues, one monotone fetch chain).
/// 4. **Pipelined ≤ sequential** holds in BOTH modes, multi-frame too.
#[test]
fn prop_exact_vs_halo_admission_differential() {
    forall(Config::default().cases(10), |g| {
        let w = [12usize, 16, 20][g.usize_in(0, 2)];
        let n_convs = g.usize_in(2, 3);
        let mut layers = Vec::new();
        for i in 0..n_convs {
            layers.push(
                GemmLayer::new(
                    format!("c{}", i),
                    w * w,
                    g.usize_in(20, 60),
                    g.usize_in(1, 3),
                )
                .with_geom(ConvGeom::new(3, 1, 1, w)),
            );
        }
        layers.push(GemmLayer::fc("fc", 64, g.usize_in(2, 6)));
        let wl = Workload::new("prop_diff", layers);
        let mut cfg = AcceleratorConfig::oxbnn_5();
        cfg.n = g.usize_in(4, 12);
        cfg.xpe_total = g.usize_in(4, 12);
        cfg.bitcount = BitcountMode::Pca { gamma: 1 << 20 };
        let plan = ExecutionPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal);

        // (1) Pointwise: exact ≤ halo on every consumer VDP.
        let exact_fp = FramePlan::new(&plan, 1);
        let halo_fp =
            FramePlan::with_admission(&plan, 1, AdmissionMode::RasterHalo(0.125));
        for unit in 1..wl.layers.len() {
            for v in 0..exact_fp.layer_plan(unit).vdp_count() {
                prop_assert(
                    exact_fp.need_acts(unit, v) <= halo_fp.need_acts(unit, v),
                    &format!("unit {} vdp {}: exact above halo", unit, v),
                )?;
            }
        }

        // (2) + (3): single-frame differential.
        let seq = simulate_frame_planned(&plan);
        let exact =
            simulate_frames_pipelined_admission(&plan, 1, AdmissionMode::Exact);
        let halo = simulate_frames_pipelined_admission(
            &plan,
            1,
            AdmissionMode::RasterHalo(0.125),
        );
        for key in ["passes", "pca_readouts", "activations", "psums"] {
            prop_assert_eq(exact.stats.counter(key), halo.stats.counter(key))?;
            prop_assert_eq(exact.stats.counter(key), seq.stats.counter(key))?;
        }
        for (e, h) in exact.layers.iter().zip(&halo.layers) {
            prop_assert_eq(e.passes, h.passes)?;
            prop_assert_eq(e.pca_readouts, h.pca_readouts)?;
            prop_assert_eq(e.psums, h.psums)?;
            prop_assert_eq(e.activations, h.activations)?;
        }
        prop_assert_eq(exact.stats.counter("clamped_events"), 0)?;
        prop_assert_eq(halo.stats.counter("clamped_events"), 0)?;
        prop_assert(
            exact.batch_latency_s <= halo.batch_latency_s * (1.0 + 1e-9),
            &format!(
                "exact makespan {} above halo {}",
                exact.batch_latency_s, halo.batch_latency_s
            ),
        )?;

        // (4) Pipelined ≤ sequential in both modes, and on a multi-frame
        // batch the exact-admission makespan never exceeds the multiply.
        prop_assert(
            exact.frame_latency_s <= seq.frame_latency_s * (1.0 + 1e-9),
            "exact pipelined frame slower than sequential",
        )?;
        prop_assert(
            halo.frame_latency_s <= seq.frame_latency_s * (1.0 + 1e-9),
            "halo pipelined frame slower than sequential",
        )?;
        let frames = g.usize_in(2, 3);
        let batch =
            simulate_frames_pipelined_admission(&plan, frames, AdmissionMode::Exact);
        prop_assert_eq(
            batch.stats.counter("passes"),
            frames as u64 * seq.stats.counter("passes"),
        )?;
        prop_assert(
            batch.batch_latency_s
                <= frames as f64 * seq.frame_latency_s * (1.0 + 1e-9),
            "exact multi-frame batch exceeds the sequential multiply",
        )
    });
}

/// ISSUE-9 scale-out invariants. Over random layer chains, both shard
/// policies and K ∈ {1, 2, 3, 4, 8}:
///
/// 1. **Conservation** — the sharded event space executes exactly the
///    unsharded per-layer transaction multisets (scale-out moves work
///    across chips, it never invents or drops any), with zero past-time
///    clamps.
/// 2. **K = 1 identity** — a one-chip shard is the unsharded run, with
///    an exactly equal makespan.
/// 3. **Bounded slowdown** — the K-chip makespan never exceeds the
///    1-chip makespan plus a generous serialized-link allowance (the
///    link is the only thing sharding ADDS; everything else only gains
///    parallel capacity).
/// 4. **Work-conservation floor** — the makespan is never below any
///    chip's accumulated PASS occupancy spread over its XPEs.
#[test]
fn prop_sharded_execution_conserves_and_scales() {
    use oxbnn::arch::workload_sim::simulate_frames_sharded;
    use oxbnn::plan::{ShardPlan, ShardPolicy};
    forall(Config::default().cases(10), |g| {
        let layers: Vec<GemmLayer> = (0..g.usize_in(2, 4))
            .map(|i| {
                GemmLayer::new(
                    format!("l{}", i),
                    g.usize_in(2, 10),
                    g.usize_in(30, 160),
                    g.usize_in(1, 4),
                )
            })
            .collect();
        let wl = Workload::new("prop_shard", layers);
        let mut cfg = AcceleratorConfig::oxbnn_5();
        cfg.n = g.usize_in(4, 16);
        cfg.xpe_total = g.usize_in(4, 20);
        cfg.bitcount = BitcountMode::Pca { gamma: 1 << 20 };
        let frames = g.usize_in(1, 3);
        let plan = ExecutionPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal);
        let base = simulate_frames_pipelined(&plan, frames);
        for shard_policy in ShardPolicy::all() {
            for k in [1usize, 2, 3, 4, 8] {
                let shard =
                    ShardPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal, k, shard_policy);
                let t = simulate_frames_sharded(&shard, frames);
                // (1) conservation, per layer and whole-run.
                for (lt, lb) in t.layers.iter().zip(&base.layers) {
                    prop_assert_eq(lt.passes, lb.passes)?;
                    prop_assert_eq(lt.pca_readouts, lb.pca_readouts)?;
                    prop_assert_eq(lt.psums, lb.psums)?;
                    prop_assert_eq(lt.activations, lb.activations)?;
                }
                for key in ["passes", "pca_readouts", "activations", "psums"] {
                    prop_assert_eq(t.stats.counter(key), base.stats.counter(key))?;
                }
                prop_assert_eq(t.stats.counter("clamped_events"), 0)?;
                // (2) K = 1 is THE unsharded run.
                if k == 1 {
                    prop_assert(
                        t.batch_latency_s == base.batch_latency_s
                            && t.frame_latency_s == base.frame_latency_s,
                        "K=1 shard diverged from the unsharded event space",
                    )?;
                    prop_assert_eq(t.link_transfers, 0)?;
                }
                // (3) bounded slowdown: base makespan + 2x the batch's
                // serialized link work (occupancy of every transfer plus
                // one hop latency per crossing edge per frame).
                let edges =
                    (0..wl.layers.len()).filter(|&l| shard.edge_crosses(l)).count();
                let slack = 2.0
                    * frames as f64
                    * (edges as f64 + 1.0)
                    * (shard.transfers_per_frame() as f64 * shard.link.occupancy_s()
                        + shard.link.latency_s);
                prop_assert(
                    t.batch_latency_s <= base.batch_latency_s * (1.0 + 1e-9) + slack,
                    &format!(
                        "[{:?} K={}] makespan {} above base {} + link slack {}",
                        shard_policy, k, t.batch_latency_s, base.batch_latency_s, slack
                    ),
                )?;
                // (4) no chip's work fits below the makespan floor.
                let per_chip = shard.per_chip_xpes() as f64;
                for busy in &t.chip_busy_s {
                    prop_assert(
                        t.batch_latency_s >= busy / per_chip - 1e-12,
                        "makespan below a chip's busy/XPE work floor",
                    )?;
                }
            }
        }
        Ok(())
    });
}

/// ISSUE-10 tentpole invariants: bounded work-stealing past
/// admission-blocked units is a pure schedule permutation.
///
/// For random conv-chain + FC-tail workloads (the shapes that actually
/// park XPEs on receptive-field thresholds), both admission modes and
/// K ∈ {1, 2, 4} chips under both shard policies:
///
/// 1. **Conservation** — stealing on vs off executes the identical
///    per-layer PASS/readout/psum/activation multisets (a steal reorders
///    admitted work, it never invents or drops any).
/// 2. **Never slower** — the steal-on makespan ≤ the steal-off makespan:
///    the stall-floor bound returns every thief before the earliest
///    possible wake of its blocked unit, so no critical path grows.
/// 3. **Pipelined ≤ sequential survives stealing** (K = 1): the PR-4
///    guarantee holds with the thief scheduler on, frame 0 and whole
///    batch alike.
/// 4. Zero event-budget clamps everywhere, and the strict frontier
///    reports zero steal counters.
#[test]
fn prop_steal_conserves_and_never_slows() {
    use oxbnn::plan::{ShardPlan, ShardPolicy};
    forall(Config::default().cases(6), |g| {
        let w = [8usize, 12, 16][g.usize_in(0, 2)];
        let mut layers = Vec::new();
        for i in 0..g.usize_in(2, 3) {
            layers.push(
                GemmLayer::new(
                    format!("c{}", i),
                    w * w,
                    g.usize_in(20, 60),
                    g.usize_in(1, 3),
                )
                .with_geom(ConvGeom::new(3, 1, 1, w)),
            );
        }
        layers.push(GemmLayer::fc("fc", 64, g.usize_in(2, 6)));
        let wl = Workload::new("prop_steal", layers);
        let mut cfg = AcceleratorConfig::oxbnn_5();
        cfg.n = g.usize_in(4, 12);
        cfg.xpe_total = g.usize_in(4, 12);
        cfg.bitcount = BitcountMode::Pca { gamma: 1 << 20 };
        let frames = g.usize_in(2, 3);
        let plan = ExecutionPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal);
        let seq = simulate_frame_planned(&plan);
        for admission in [AdmissionMode::Exact, AdmissionMode::RasterHalo(0.125)] {
            // (3) the PR-4 guarantee with the thief scheduler on.
            let on = simulate_frames_pipelined_opts(&plan, frames, admission, true);
            let off = simulate_frames_pipelined_opts(&plan, frames, admission, false);
            prop_assert_eq(off.stats.counter("steal_dispatches"), 0)?;
            prop_assert_eq(off.stats.counter("stolen_passes"), 0)?;
            for (a, b) in on.layers.iter().zip(&off.layers) {
                prop_assert_eq(a.passes, b.passes)?;
                prop_assert_eq(a.pca_readouts, b.pca_readouts)?;
                prop_assert_eq(a.psums, b.psums)?;
                prop_assert_eq(a.activations, b.activations)?;
            }
            for key in ["passes", "pca_readouts", "activations", "psums"] {
                prop_assert_eq(on.stats.counter(key), off.stats.counter(key))?;
            }
            prop_assert_eq(on.stats.counter("clamped_events"), 0)?;
            prop_assert_eq(off.stats.counter("clamped_events"), 0)?;
            prop_assert(
                on.batch_latency_s <= off.batch_latency_s * (1.0 + 1e-9),
                &format!(
                    "steal-on makespan {} above steal-off {}",
                    on.batch_latency_s, off.batch_latency_s
                ),
            )?;
            prop_assert(
                on.frame_latency_s <= seq.frame_latency_s * (1.0 + 1e-9),
                "stealing broke pipelined-frame ≤ sequential-frame",
            )?;
            prop_assert(
                on.batch_latency_s <= frames as f64 * seq.frame_latency_s * (1.0 + 1e-9),
                "stealing broke pipelined-batch ≤ sequential multiply",
            )?;
            // Frame completions stay in order: last-layer work is never
            // stolen, so monotonicity survives the thief scheduler.
            for pair in on.frame_done_s.windows(2) {
                prop_assert(
                    pair[1] >= pair[0] - 1e-12,
                    "stealing reordered frame completions",
                )?;
            }
        }
        // (1) + (2) across chip counts and shard policies.
        for shard_policy in ShardPolicy::all() {
            for k in [1usize, 2, 4] {
                let shard =
                    ShardPlan::compile(&cfg, &wl, MappingPolicy::PcaLocal, k, shard_policy);
                let on = simulate_frames_sharded_opts(
                    &shard,
                    frames,
                    AdmissionMode::Exact,
                    true,
                );
                let off = simulate_frames_sharded_opts(
                    &shard,
                    frames,
                    AdmissionMode::Exact,
                    false,
                );
                for (a, b) in on.layers.iter().zip(&off.layers) {
                    prop_assert_eq(a.passes, b.passes)?;
                    prop_assert_eq(a.pca_readouts, b.pca_readouts)?;
                    prop_assert_eq(a.psums, b.psums)?;
                    prop_assert_eq(a.activations, b.activations)?;
                }
                for key in ["passes", "pca_readouts", "activations", "psums"] {
                    prop_assert_eq(on.stats.counter(key), off.stats.counter(key))?;
                }
                prop_assert_eq(on.stats.counter("clamped_events"), 0)?;
                prop_assert_eq(off.stats.counter("clamped_events"), 0)?;
                prop_assert(
                    on.batch_latency_s <= off.batch_latency_s * (1.0 + 1e-9),
                    &format!(
                        "[{:?} K={}] steal-on makespan {} above steal-off {}",
                        shard_policy, k, on.batch_latency_s, off.batch_latency_s
                    ),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_numbers_and_strings() {
    forall(Config::default().cases(200), |g| {
        let n = g.usize_in(0, 1_000_000) as f64 / 97.0;
        let s: String = (0..g.usize_in(0, 20))
            .map(|_| char::from_u32(g.usize_in(32, 0x24F) as u32).unwrap_or('x'))
            .collect();
        let j = Json::obj(vec![
            ("n", Json::Num(n)),
            ("s", Json::Str(s.clone())),
            ("a", Json::arr_usize(&[g.usize_in(0, 99), g.usize_in(0, 99)])),
        ]);
        let back = Json::parse(&j.to_string()).map_err(|e| e.to_string())?;
        prop_assert_eq(back.get("s").and_then(Json::as_str), Some(s.as_str()))?;
        let diff = (back.get("n").unwrap().as_f64().unwrap() - n).abs();
        prop_assert(diff < 1e-9, "number roundtrip")
    });
}

#[test]
fn prop_scalability_n_monotone_in_dr() {
    let solver = ScalabilitySolver::default();
    forall(Config::default().cases(40), |g| {
        let dr1 = g.f64_in(1.0, 50.0);
        let dr2 = g.f64_in(1.0, 50.0);
        let (lo, hi) = if dr1 < dr2 { (dr1, dr2) } else { (dr2, dr1) };
        let row_lo = solver.solve(lo);
        let row_hi = solver.solve(hi);
        prop_assert(row_lo.n >= row_hi.n, "N must not grow with DR")?;
        prop_assert(
            row_lo.p_pd_opt_dbm <= row_hi.p_pd_opt_dbm + 1e-9,
            "sensitivity must relax (grow) with DR",
        )
    });
}

#[test]
fn prop_alpha_gamma_consistency() {
    forall(Config::default().cases(100), |g| {
        let dr = g.f64_in(3.0, 50.0);
        let n = g.usize_in(1, 80);
        let gamma = gamma_calibrated(dr);
        let a = alpha(gamma, n);
        prop_assert(a * n as u64 <= gamma, "alpha*N <= gamma")?;
        prop_assert((a + 1) * n as u64 > gamma, "alpha maximal")
    });
}

#[test]
fn prop_layer_perf_latency_positive_and_pca_no_worse() {
    // For any layer geometry, OXBNN (PCA) latency is <= the same photonic
    // fabric with a reduction-network bitcount.
    forall(Config::default().cases(60), |g| {
        let layer = GemmLayer::new(
            "p",
            g.usize_in(1, 256),
            g.usize_in(1, 2048),
            g.usize_in(1, 64),
        );
        let mut pca = AcceleratorConfig::oxbnn_50();
        pca.n = g.usize_in(4, 64);
        pca.xpe_total = g.usize_in(8, 512);
        pca.bitcount = BitcountMode::Pca { gamma: 8503 };
        let mut red = pca.clone();
        red.bitcount = BitcountMode::Reduction { latency_s: 3.125e-9, psum_bits: 16 };
        let p = layer_perf(&pca, &layer);
        let r = layer_perf(&red, &layer);
        prop_assert(p.latency_s > 0.0, "positive latency")?;
        prop_assert(
            p.latency_s <= r.latency_s + 1e-15,
            "PCA must never be slower than reduction on same fabric",
        )?;
        prop_assert(
            p.dynamic_energy_j <= r.dynamic_energy_j + 1e-18,
            "PCA must never burn more dynamic energy",
        )
    });
}

#[test]
fn prop_batcher_never_exceeds_max_and_preserves_order() {
    forall(Config::default().cases(80), |g| {
        let max_batch = g.usize_in(1, 16);
        let n = g.usize_in(0, 60);
        let mut b: Batcher<usize> = Batcher::new(max_batch, 0.010);
        let mut t = 0.0;
        for i in 0..n {
            t += g.f64_in(0.0, 0.005);
            b.push(i, t);
        }
        let mut drained = Vec::new();
        let mut now = t;
        loop {
            now += 0.02; // force deadline
            match b.drain(now) {
                Some(batch) => {
                    prop_assert(batch.len() <= max_batch, "batch size bound")?;
                    drained.extend(batch.into_iter().map(|p| p.item));
                }
                None => break,
            }
        }
        prop_assert_eq(drained, (0..n).collect::<Vec<_>>())
    });
}

#[test]
fn prop_router_balances_outstanding() {
    forall(Config::default().cases(60), |g| {
        let replicas = g.usize_in(1, 6);
        let requests = g.usize_in(0, 60);
        let mut r = Router::default();
        for i in 0..replicas {
            r.register("m", i);
        }
        let mut counts = vec![0usize; replicas];
        for _ in 0..requests {
            let id = r.route("m").map_err(|e| e.to_string())?;
            counts[id] += 1;
        }
        // Least-loaded routing with no completions → perfectly balanced
        // within 1.
        let min = *counts.iter().min().unwrap();
        let max = *counts.iter().max().unwrap();
        prop_assert(max - min <= 1, "outstanding imbalance > 1")?;
        prop_assert_eq(r.outstanding("m"), requests)
    });
}
